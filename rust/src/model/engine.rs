//! Forward passes: prefill (full-precision attention, per the paper's
//! protocol) and single-token decode through a pluggable [`KvCache`].

use std::sync::Arc;

use crate::cache::{CacheShape, KvCache};
use crate::dict::DictionarySet;
use crate::exec::{self, ExecPool, SendPtr};
use crate::model::weights::Weights;
use crate::tensor::{
    argmax, axpy, dot, par_matmul, par_matmul_bt, par_matmul_kmajor, rmsnorm, silu, softmax,
};

const RMS_EPS: f32 = 1e-5;

/// Precomputed RoPE tables (split-half convention, matching the JAX model).
struct Rope {
    cos: Vec<f32>, // [max_seq][half]
    sin: Vec<f32>,
    half: usize,
}

impl Rope {
    fn new(head_dim: usize, max_seq: usize, base: f32) -> Self {
        let half = head_dim / 2;
        let mut cos = vec![0.0; max_seq * half];
        let mut sin = vec![0.0; max_seq * half];
        for p in 0..max_seq {
            for i in 0..half {
                let ang = p as f32 * base.powf(-(i as f32) / half as f32);
                cos[p * half + i] = ang.cos();
                sin[p * half + i] = ang.sin();
            }
        }
        Rope { cos, sin, half }
    }

    /// Rotate one head vector in place for position `pos`.
    #[inline]
    fn apply(&self, x: &mut [f32], pos: usize) {
        let h = self.half;
        let (c, s) = (&self.cos[pos * h..(pos + 1) * h], &self.sin[pos * h..(pos + 1) * h]);
        for i in 0..h {
            let (x1, x2) = (x[i], x[i + h]);
            x[i] = x1 * c[i] - x2 * s[i];
            x[i + h] = x1 * s[i] + x2 * c[i];
        }
    }
}

/// Scratch buffers so decode allocates nothing in steady state.
struct Scratch {
    x: Vec<f32>,
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    proj: Vec<f32>,
    ff1: Vec<f32>,
    ff3: Vec<f32>,
}

/// Scratch for the batched decode path: the same buffers as [`Scratch`]
/// with a leading batch dimension, grown to the largest batch seen.
#[derive(Default)]
struct BatchScratch {
    x: Vec<f32>,
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    proj: Vec<f32>,
    ff1: Vec<f32>,
    ff3: Vec<f32>,
    /// round-level shared-qd path (DESIGN.md §10): gathered member query
    /// rows (`qg`), the per-round `qᵀD_k` GEMM output (`qd_round`) and the
    /// per-session base value z-bins (`z_round`). Sized per layer inside
    /// `decode_batch` — their extents depend on each group's dictionary.
    qg: Vec<f32>,
    qd_round: Vec<f32>,
    z_round: Vec<f32>,
}

impl BatchScratch {
    fn ensure(&mut self, bsz: usize, d: usize, qd: usize, kvd: usize, d_ff: usize) {
        let grow = |v: &mut Vec<f32>, n: usize| {
            if v.len() < n {
                v.resize(n, 0.0);
            }
        };
        grow(&mut self.x, bsz * d);
        grow(&mut self.h, bsz * d);
        grow(&mut self.q, bsz * qd);
        grow(&mut self.k, bsz * kvd);
        grow(&mut self.v, bsz * kvd);
        grow(&mut self.attn, bsz * qd);
        grow(&mut self.proj, bsz * d);
        grow(&mut self.ff1, bsz * d_ff);
        grow(&mut self.ff3, bsz * d_ff);
    }
}

/// The native engine: owns weights + RoPE tables; caches are passed in.
/// All hot loops (GEMMs, prefill attention heads, per-session cache traffic
/// in [`Engine::decode_batch`], the unembedding) run on the engine's
/// [`ExecPool`]; every parallel kernel partitions disjoint output elements,
/// so results are bitwise identical at every thread count (DESIGN.md §7).
pub struct Engine {
    pub weights: Weights,
    rope: Rope,
    pool: Arc<ExecPool>,
    scratch: std::sync::Mutex<Scratch>,
    batch_scratch: std::sync::Mutex<BatchScratch>,
    /// Round-level shared-dictionary query GEMM in [`Engine::decode_batch`]
    /// (DESIGN.md §10). On by default; `LEXICO_ROUND_QD=0` (read once at
    /// construction) or [`Engine::set_round_shared_qd`] falls back to the
    /// per-session attend fan-out. Both paths are bitwise identical — the
    /// switch exists for benchmarking and bisection, not correctness.
    round_shared_qd: bool,
}

/// How many trailing prompt queries are handed to the cache as the
/// observation window (SnapKV/PyramidKV); bounded by the prompt length.
pub const OBS_WINDOW: usize = 8;

/// Captured prefill state for shared-prefix serving: the dense per-layer
/// K/V rows (post-RoPE — exactly the arrays prefill hands to the cache)
/// plus the last-token logits. The server's prefix cache stores one of
/// these per cached prompt prefix; a later request that shares the prefix
/// runs [`Engine::prefill_suffix`], whose suffix tokens attend in full
/// precision over these rows. Because the stored rows *are* the rows a
/// cold prefill of the full prompt would compute, the suffix pass
/// reproduces the cold computation bit for bit while doing zero
/// transformer work (matmuls, attention, OMP compression) on the prefix.
#[derive(Clone)]
pub struct PrefixState {
    /// the prefix token ids (used for longest-prefix matching)
    pub tokens: Vec<u32>,
    /// per layer, token-major `[t][kv_dim]`, RoPE already applied
    pub ks: Vec<Vec<f32>>,
    /// per layer, token-major `[t][kv_dim]`
    pub vs: Vec<Vec<f32>>,
    /// logits of the last prefix token (exact-hit fast path)
    pub logits: Vec<f32>,
}

impl PrefixState {
    /// A zero-token state: the starting point of a chunked prefill
    /// ([`Engine::prefill_chunk`] extends it in place, one chunk at a
    /// time, until the whole prompt has landed).
    pub fn empty(n_layers: usize) -> Self {
        PrefixState {
            tokens: Vec::new(),
            ks: vec![Vec::new(); n_layers],
            vs: vec![Vec::new(); n_layers],
            logits: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Resident bytes of the stored dense rows (f32), charged against the
    /// serving KV budget while the entry lives in the prefix cache.
    pub fn bytes(&self) -> f64 {
        let rows: usize = self.ks.iter().chain(&self.vs).map(Vec::len).sum();
        ((rows + self.logits.len()) * 4) as f64
    }
}

impl Engine {
    /// Engine on the process-default pool (`LEXICO_THREADS`, then available
    /// parallelism).
    pub fn new(weights: Weights) -> Self {
        Self::with_pool(weights, exec::default_pool())
    }

    /// Engine on an explicit pool (thread-count sweeps, determinism tests).
    pub fn with_pool(weights: Weights, pool: Arc<ExecPool>) -> Self {
        let cfg = weights.cfg;
        let rope = Rope::new(cfg.head_dim, cfg.max_seq, 10000.0);
        let scratch = Scratch {
            x: vec![0.0; cfg.d_model],
            h: vec![0.0; cfg.d_model],
            q: vec![0.0; cfg.q_dim()],
            k: vec![0.0; cfg.kv_dim()],
            v: vec![0.0; cfg.kv_dim()],
            attn: vec![0.0; cfg.q_dim()],
            proj: vec![0.0; cfg.d_model],
            ff1: vec![0.0; cfg.d_ff],
            ff3: vec![0.0; cfg.d_ff],
        };
        Engine {
            weights,
            rope,
            pool,
            scratch: std::sync::Mutex::new(scratch),
            batch_scratch: std::sync::Mutex::new(BatchScratch::default()),
            round_shared_qd: std::env::var("LEXICO_ROUND_QD").map(|v| v != "0").unwrap_or(true),
        }
    }

    /// The pool this engine's kernels run on (the batcher shares it with
    /// the caches it builds).
    pub fn pool(&self) -> &Arc<ExecPool> {
        &self.pool
    }

    /// Toggle the round-level shared-qd decode path (parity tests, the
    /// old-vs-round bench series). Both settings produce bitwise-identical
    /// logits.
    pub fn set_round_shared_qd(&mut self, on: bool) {
        self.round_shared_qd = on;
    }

    pub fn shape(&self) -> CacheShape {
        let c = self.weights.cfg;
        CacheShape {
            n_layers: c.n_layers,
            n_heads: c.n_heads,
            n_kv_heads: c.n_kv_heads,
            head_dim: c.head_dim,
        }
    }

    /// Prefill: full causal attention in full precision over the prompt,
    /// handing each layer's K/V states (plus the last-`OBS_WINDOW` queries)
    /// to the cache. Returns the logits of the last prompt token.
    pub fn prefill(&self, tokens: &[u32], cache: &mut dyn KvCache) -> Vec<f32> {
        self.prefill_part(None, tokens, cache, false).0
    }

    /// [`Engine::prefill`] that also captures the dense per-layer K/V rows
    /// as a [`PrefixState`] for the shared-prefix cache. The capture is a
    /// pure copy of arrays the prefill computes anyway, so the returned
    /// logits — and the cache state — are bitwise identical to `prefill`.
    pub fn prefill_capture(
        &self,
        tokens: &[u32],
        cache: &mut dyn KvCache,
    ) -> (Vec<f32>, PrefixState) {
        let (logits, state) = self.prefill_part(None, tokens, cache, true);
        (logits, state.expect("capture requested"))
    }

    /// Prefill only `suffix`, resuming after a cached prefix: suffix tokens
    /// attend in full precision over the stored prefix K/V rows plus each
    /// other (causally), and the cache — which must already hold the prefix
    /// (typically a fork of the prefix prototype) — ingests the suffix
    /// rows only. For backends whose [`crate::cache::CacheCaps::split_prefill_exact`]
    /// holds, the resulting cache state and logits are bitwise identical
    /// to a cold [`Engine::prefill`] of `prefix ++ suffix`; the prefix
    /// itself costs zero transformer work here. An empty suffix returns
    /// the stored prefix logits untouched.
    pub fn prefill_suffix(
        &self,
        prefix: &PrefixState,
        suffix: &[u32],
        cache: &mut dyn KvCache,
    ) -> Vec<f32> {
        self.prefill_part(Some(prefix), suffix, cache, false).0
    }

    /// [`Engine::prefill_suffix`] that also captures the *extended* state
    /// (prefix rows ++ suffix rows) so the longer prompt can itself be
    /// inserted into the prefix cache.
    pub fn prefill_suffix_capture(
        &self,
        prefix: &PrefixState,
        suffix: &[u32],
        cache: &mut dyn KvCache,
    ) -> (Vec<f32>, PrefixState) {
        let (logits, state) = self.prefill_part(Some(prefix), suffix, cache, true);
        (logits, state.expect("capture requested"))
    }

    /// Advance a *chunked* prefill by `chunk` prompt tokens: processes
    /// positions `[state.len(), state.len() + chunk.len())` against the
    /// session's existing cache (which must already hold exactly the
    /// `state.len()` prefix tokens) and extends `state` in place with the
    /// chunk's dense K/V rows, so the next chunk attends causally over
    /// everything before it. Returns the logits of the chunk's last token.
    ///
    /// Parity: a chunked prefill — any partition of the prompt into
    /// chunks, down to one token at a time — performs the identical
    /// floating-point operations in the identical order as one monolithic
    /// [`Engine::prefill`] for every position (each chunk is exactly a
    /// [`Engine::prefill_suffix`] resume, and the prefix rows occupy the
    /// same score slots either way), so the final logits are bitwise
    /// identical and the cache state is bitwise identical for every
    /// backend whose [`crate::cache::CacheCaps::split_prefill_exact`] holds. The batcher
    /// relies on this to schedule prefill one budgeted chunk per round
    /// without perturbing pinned transcripts (DESIGN.md §9).
    ///
    /// Start from [`PrefixState::empty`] for a cold prompt, or from a
    /// clone of a prefix-cache entry's state to resume after a shared
    /// prefix. An empty chunk returns the stored logits untouched.
    pub fn prefill_chunk(
        &self,
        state: &mut PrefixState,
        chunk: &[u32],
        cache: &mut dyn KvCache,
    ) -> Vec<f32> {
        let cfg = self.weights.cfg;
        assert_eq!(
            state.ks.len(),
            cfg.n_layers,
            "state must come from PrefixState::empty(n_layers) or a capture"
        );
        if chunk.is_empty() {
            return state.logits.clone();
        }
        let (logits, rows) = self.prefill_core(Some(&*state), chunk, cache, true);
        let (nks, nvs) = rows.expect("rows requested");
        for (li, (nk, nv)) in nks.into_iter().zip(nvs).enumerate() {
            state.ks[li].extend_from_slice(&nk);
            state.vs[li].extend_from_slice(&nv);
        }
        state.tokens.extend_from_slice(chunk);
        state.logits = logits.clone();
        logits
    }

    /// [`Engine::prefill_core`] plus full-state capture: concatenates the
    /// prefix rows with the chunk's new rows into a complete
    /// [`PrefixState`] (what `prefill_capture`/`prefill_suffix_capture`
    /// hand to the prefix cache).
    fn prefill_part(
        &self,
        prefix: Option<&PrefixState>,
        tokens: &[u32],
        cache: &mut dyn KvCache,
        capture: bool,
    ) -> (Vec<f32>, Option<PrefixState>) {
        if tokens.is_empty() {
            let p = prefix.expect("prefill of zero tokens without a prefix");
            return (p.logits.clone(), capture.then(|| p.clone()));
        }
        let (logits, rows) = self.prefill_core(prefix, tokens, cache, capture);
        let state = rows.map(|(nks, nvs)| {
            let cfg = self.weights.cfg;
            let mut ks = Vec::with_capacity(cfg.n_layers);
            let mut vs = Vec::with_capacity(cfg.n_layers);
            for li in 0..cfg.n_layers {
                let (pk, pv): (&[f32], &[f32]) = match prefix {
                    Some(p) => (&p.ks[li], &p.vs[li]),
                    None => (&[], &[]),
                };
                let mut kk = Vec::with_capacity(pk.len() + nks[li].len());
                kk.extend_from_slice(pk);
                kk.extend_from_slice(&nks[li]);
                let mut vv = Vec::with_capacity(pv.len() + nvs[li].len());
                vv.extend_from_slice(pv);
                vv.extend_from_slice(&nvs[li]);
                ks.push(kk);
                vs.push(vv);
            }
            let mut ids = prefix.map_or_else(Vec::new, |p| p.tokens.clone());
            ids.extend_from_slice(tokens);
            PrefixState { tokens: ids, ks, vs, logits: logits.clone() }
        });
        (logits, state)
    }

    /// Shared prefill core. With `prefix = None` (or an empty prefix) this
    /// is the cold path (tokens are the whole prompt); with a prefix it is
    /// the resume path. Loop structure and accumulation order are
    /// identical in both cases — the prefix rows simply occupy score slots
    /// `0..p0` — so resume is bitwise equal to cold on the overlapping
    /// computation. With `want_rows` it also returns the per-layer dense
    /// K/V rows of `tokens` *only* (the chunk's new rows, post-RoPE), so
    /// chunked prefill can extend its state without re-copying the prefix
    /// every chunk.
    fn prefill_core(
        &self,
        prefix: Option<&PrefixState>,
        tokens: &[u32],
        cache: &mut dyn KvCache,
        want_rows: bool,
    ) -> (Vec<f32>, Option<(Vec<Vec<f32>>, Vec<Vec<f32>>)>) {
        let cfg = self.weights.cfg;
        let p0 = prefix.map_or(0, |p| p.len());
        let t = tokens.len();
        assert!(t > 0, "prefill_core needs at least one token");
        assert!(p0 + t <= cfg.max_seq, "prompt length {}", p0 + t);
        if let Some(p) = prefix {
            assert_eq!(p.ks.len(), cfg.n_layers, "prefix state layer mismatch");
        }
        let d = cfg.d_model;
        let m = cfg.head_dim;
        let (qd, kvd) = (cfg.q_dim(), cfg.kv_dim());
        let scale = 1.0 / (m as f32).sqrt();

        let mut x = vec![0.0; t * d];
        for (ti, &tok) in tokens.iter().enumerate() {
            x[ti * d..(ti + 1) * d]
                .copy_from_slice(&self.weights.embed[tok as usize * d..(tok as usize + 1) * d]);
        }
        let mut h = vec![0.0; t * d];
        let mut q = vec![0.0; t * qd];
        let mut k = vec![0.0; t * kvd];
        let mut v = vec![0.0; t * kvd];
        let mut attn = vec![0.0; t * qd];
        let mut proj = vec![0.0; t * d];
        // per-head score buffers for the sharded attention (allocated once
        // per prefill, reused across layers; each head owns exactly one)
        let mut head_scores: Vec<Vec<f32>> = vec![vec![0.0f32; p0 + t]; cfg.n_heads];
        let mut ff1 = vec![0.0; t * cfg.d_ff];
        let mut ff3 = vec![0.0; t * cfg.d_ff];
        let mut new_ks: Vec<Vec<f32>> = Vec::new();
        let mut new_vs: Vec<Vec<f32>> = Vec::new();

        for (li, lw) in self.weights.layers.iter().enumerate() {
            for ti in 0..t {
                rmsnorm(&mut h[ti * d..(ti + 1) * d], &x[ti * d..(ti + 1) * d], &lw.ln1, RMS_EPS);
            }
            par_matmul(&self.pool, &mut q, &h, &lw.wq, t, d, qd);
            par_matmul(&self.pool, &mut k, &h, &lw.wk, t, d, kvd);
            par_matmul(&self.pool, &mut v, &h, &lw.wv, t, d, kvd);
            for ti in 0..t {
                for hh in 0..cfg.n_heads {
                    self.rope.apply(&mut q[ti * qd + hh * m..ti * qd + (hh + 1) * m], p0 + ti);
                }
                for g in 0..cfg.n_kv_heads {
                    self.rope.apply(&mut k[ti * kvd + g * m..ti * kvd + (g + 1) * m], p0 + ti);
                }
            }
            // full-precision causal attention (paper: prefill attends in
            // FP); prefix rows fill score slots 0..p0
            let (pks, pvs): (&[f32], &[f32]) = match prefix {
                Some(p) => (&p.ks[li], &p.vs[li]),
                None => (&[], &[]),
            };
            // one shard per query head: each head owns its own columns of
            // `attn` and a private score buffer, so the per-head
            // computation is the exact sequential sequence regardless of
            // the thread count
            attn.fill(0.0);
            {
                let group = cfg.group();
                let (qr, kr, vr): (&[f32], &[f32], &[f32]) = (&q, &k, &v);
                let attn_ptr = SendPtr::new(attn.as_mut_ptr());
                let scores_ptr = SendPtr::new(head_scores.as_mut_ptr());
                self.pool.parallel_for(cfg.n_heads, move |hh| {
                    let g = hh / group;
                    // SAFETY: head hh exclusively owns its score buffer.
                    let scores: &mut Vec<f32> = unsafe { &mut *scores_ptr.get().add(hh) };
                    for ti in 0..t {
                        let qrow = &qr[ti * qd + hh * m..ti * qd + (hh + 1) * m];
                        for tj in 0..p0 {
                            scores[tj] =
                                dot(qrow, &pks[tj * kvd + g * m..tj * kvd + (g + 1) * m]) * scale;
                        }
                        for tj in 0..=ti {
                            scores[p0 + tj] =
                                dot(qrow, &kr[tj * kvd + g * m..tj * kvd + (g + 1) * m]) * scale;
                        }
                        softmax(&mut scores[..p0 + ti + 1]);
                        // SAFETY: head hh exclusively owns this attn column.
                        let orow = unsafe {
                            std::slice::from_raw_parts_mut(attn_ptr.get().add(ti * qd + hh * m), m)
                        };
                        for tj in 0..p0 {
                            crate::tensor::axpy(
                                orow,
                                scores[tj],
                                &pvs[tj * kvd + g * m..tj * kvd + (g + 1) * m],
                            );
                        }
                        for tj in 0..=ti {
                            crate::tensor::axpy(
                                orow,
                                scores[p0 + tj],
                                &vr[tj * kvd + g * m..tj * kvd + (g + 1) * m],
                            );
                        }
                    }
                });
            }
            // hand the layer's KV states + observation-window queries over
            let w = OBS_WINDOW.min(t);
            cache.ingest_prefill(li, &k, &v, t, &q[(t - w) * qd..], w);
            if want_rows {
                // the chunk's rows only — the caller already owns the
                // prefix rows, so chunked prefill stays O(chunk) per chunk
                new_ks.push(k.clone());
                new_vs.push(v.clone());
            }

            par_matmul(&self.pool, &mut proj, &attn, &lw.wo, t, qd, d);
            for i in 0..t * d {
                x[i] += proj[i];
            }
            for ti in 0..t {
                rmsnorm(&mut h[ti * d..(ti + 1) * d], &x[ti * d..(ti + 1) * d], &lw.ln2, RMS_EPS);
            }
            par_matmul(&self.pool, &mut ff1, &h, &lw.w1, t, d, cfg.d_ff);
            par_matmul(&self.pool, &mut ff3, &h, &lw.w3, t, d, cfg.d_ff);
            for i in 0..t * cfg.d_ff {
                ff1[i] = silu(ff1[i]) * ff3[i];
            }
            par_matmul(&self.pool, &mut proj, &ff1, &lw.w2, t, cfg.d_ff, d);
            for i in 0..t * d {
                x[i] += proj[i];
            }
        }
        // logits of the last token only
        let last = &x[(t - 1) * d..t * d];
        let mut hn = vec![0.0; d];
        rmsnorm(&mut hn, last, &self.weights.lnf, RMS_EPS);
        let logits = self.logits(&hn);
        let rows = want_rows.then_some((new_ks, new_vs));
        (logits, rows)
    }

    /// One decode step: token at absolute position `pos` (0-based).
    /// The cache must already hold positions `0..pos`.
    pub fn decode_step(&self, token: u32, pos: usize, cache: &mut dyn KvCache) -> Vec<f32> {
        let cfg = self.weights.cfg;
        assert!(pos < cfg.max_seq, "position {pos} ≥ max_seq");
        let d = cfg.d_model;
        let m = cfg.head_dim;
        let (qd, kvd) = (cfg.q_dim(), cfg.kv_dim());
        let mut s = self.scratch.lock().unwrap();
        let s = &mut *s;
        s.x.copy_from_slice(&self.weights.embed[token as usize * d..(token as usize + 1) * d]);

        for (li, lw) in self.weights.layers.iter().enumerate() {
            rmsnorm(&mut s.h, &s.x, &lw.ln1, RMS_EPS);
            par_matmul(&self.pool, &mut s.q, &s.h, &lw.wq, 1, d, qd);
            par_matmul(&self.pool, &mut s.k, &s.h, &lw.wk, 1, d, kvd);
            par_matmul(&self.pool, &mut s.v, &s.h, &lw.wv, 1, d, kvd);
            for hh in 0..cfg.n_heads {
                self.rope.apply(&mut s.q[hh * m..(hh + 1) * m], pos);
            }
            for g in 0..cfg.n_kv_heads {
                self.rope.apply(&mut s.k[g * m..(g + 1) * m], pos);
            }
            cache.append(li, &s.k, &s.v);
            cache.attend(li, &s.q, &mut s.attn);
            par_matmul(&self.pool, &mut s.proj, &s.attn, &lw.wo, 1, qd, d);
            for i in 0..d {
                s.x[i] += s.proj[i];
            }
            rmsnorm(&mut s.h, &s.x, &lw.ln2, RMS_EPS);
            par_matmul(&self.pool, &mut s.ff1, &s.h, &lw.w1, 1, d, cfg.d_ff);
            par_matmul(&self.pool, &mut s.ff3, &s.h, &lw.w3, 1, d, cfg.d_ff);
            for i in 0..cfg.d_ff {
                s.ff1[i] = silu(s.ff1[i]) * s.ff3[i];
            }
            par_matmul(&self.pool, &mut s.proj, &s.ff1, &lw.w2, 1, cfg.d_ff, d);
            for i in 0..d {
                s.x[i] += s.proj[i];
            }
        }
        rmsnorm(&mut s.h, &s.x, &self.weights.lnf, RMS_EPS);
        self.logits(&s.h)
    }

    /// Layer-major batched decode: advance `B` independent sessions by one
    /// token each. Session `b` decodes `tokens[b]` at absolute position
    /// `positions[b]` through its own cache `caches[b]` (which must already
    /// hold positions `0..positions[b]`).
    ///
    /// Hidden states are stacked into `[B, d_model]` rows and every weight
    /// matrix is driven through the k-major GEMM, so each weight streams
    /// from memory once per layer per round instead of once per session —
    /// the batch-first serving pipeline. Sessions whose caches share a
    /// dictionary set additionally share the query–dictionary projection
    /// and the value-atom pass: one `qᵀD_k` GEMM and one streaming pass
    /// over `D_v` per (round, layer, dictionary) serve every member
    /// session (DESIGN.md §10); scoring, softmax, adaptive extensions and
    /// the recency buffer stay per-session. Other backends keep the plain
    /// per-session attend.
    ///
    /// Parity: per session this performs the identical floating-point
    /// operations in the identical order as [`Engine::decode_step`]
    /// (`par_matmul_kmajor` accumulates bitwise like `matmul`, each round
    /// GEMM element is one whole canonical dot, and the per-session pool
    /// shards compute disjoint state), so the returned logits — and
    /// therefore greedy decoding — are token-for-token identical to the
    /// sequential path at every batch size and thread count, with the
    /// shared-qd path on or off.
    pub fn decode_batch(
        &self,
        tokens: &[u32],
        positions: &[usize],
        caches: &mut [&mut dyn KvCache],
    ) -> Vec<Vec<f32>> {
        let bsz = tokens.len();
        assert_eq!(positions.len(), bsz, "tokens/positions length mismatch");
        assert_eq!(caches.len(), bsz, "tokens/caches length mismatch");
        if bsz == 0 {
            return Vec::new();
        }
        let cfg = self.weights.cfg;
        for &p in positions {
            assert!(p < cfg.max_seq, "position {p} ≥ max_seq");
        }
        let d = cfg.d_model;
        let m = cfg.head_dim;
        let (qd, kvd) = (cfg.q_dim(), cfg.kv_dim());
        let mut s = self.batch_scratch.lock().unwrap();
        let s = &mut *s;
        s.ensure(bsz, d, qd, kvd, cfg.d_ff);
        let x = &mut s.x[..bsz * d];
        let h = &mut s.h[..bsz * d];
        let q = &mut s.q[..bsz * qd];
        let k = &mut s.k[..bsz * kvd];
        let v = &mut s.v[..bsz * kvd];
        let attn = &mut s.attn[..bsz * qd];
        let proj = &mut s.proj[..bsz * d];
        let ff1 = &mut s.ff1[..bsz * cfg.d_ff];
        let ff3 = &mut s.ff3[..bsz * cfg.d_ff];
        let qg = &mut s.qg;
        let qd_round = &mut s.qd_round;
        let z_round = &mut s.z_round;

        // Round-level shared-dictionary grouping (DESIGN.md §10): sessions
        // whose caches score against the *same* `Arc<DictionarySet>` share
        // one `qᵀD_k` GEMM and one value-atom streaming pass per layer —
        // Lexico's universal dictionary makes the projection input-agnostic
        // across sessions, so the round pays O(N·m) once instead of once
        // per session. `slot[bi] = (group, member)` locates a session's
        // rows inside its group's blocks; `None` keeps the plain per-cache
        // attend fan-out (the 6 non-lexico backends).
        let nh = cfg.n_heads;
        let mut groups: Vec<(Arc<DictionarySet>, Vec<usize>)> = Vec::new();
        let mut slot: Vec<Option<(usize, usize)>> = vec![None; bsz];
        if self.round_shared_qd {
            for bi in 0..bsz {
                if let Some(dicts) = caches[bi].shared_dicts() {
                    let gi = match groups.iter().position(|(dset, _)| Arc::ptr_eq(dset, &dicts)) {
                        Some(gi) => gi,
                        None => {
                            groups.push((dicts, Vec::new()));
                            groups.len() - 1
                        }
                    };
                    slot[bi] = Some((gi, groups[gi].1.len()));
                    groups[gi].1.push(bi);
                }
            }
        }

        for (bi, &tok) in tokens.iter().enumerate() {
            x[bi * d..(bi + 1) * d].copy_from_slice(
                &self.weights.embed[tok as usize * d..(tok as usize + 1) * d],
            );
        }
        for (li, lw) in self.weights.layers.iter().enumerate() {
            for bi in 0..bsz {
                rmsnorm(&mut h[bi * d..(bi + 1) * d], &x[bi * d..(bi + 1) * d], &lw.ln1, RMS_EPS);
            }
            // one stream of each weight matrix serves every session (the
            // pool shards it by output columns — one pass in total)
            par_matmul_kmajor(&self.pool, q, h, &lw.wq, bsz, d, qd);
            par_matmul_kmajor(&self.pool, k, h, &lw.wk, bsz, d, kvd);
            par_matmul_kmajor(&self.pool, v, h, &lw.wv, bsz, d, kvd);
            for bi in 0..bsz {
                let pos = positions[bi];
                for hh in 0..cfg.n_heads {
                    self.rope.apply(&mut q[bi * qd + hh * m..bi * qd + (hh + 1) * m], pos);
                }
                for g in 0..cfg.n_kv_heads {
                    self.rope.apply(&mut k[bi * kvd + g * m..bi * kvd + (g + 1) * m], pos);
                }
            }
            // Phase 0 — round-level shared-dictionary query GEMM: for each
            // dictionary group, gather the member sessions' query rows
            // contiguously and project ALL of them onto the shared base key
            // dictionary with one `par_matmul_bt` (each output element is
            // one whole canonical dot — bitwise identical to the
            // per-session projection loops it replaces). Per-layer block
            // offsets, since dictionary sizes may differ by layer.
            let mut qd_off: Vec<usize> = vec![0];
            let mut z_off: Vec<usize> = vec![0];
            for (dicts, members) in &groups {
                qd_off.push(qd_off.last().unwrap() + members.len() * nh * dicts.keys[li].n);
                z_off.push(z_off.last().unwrap() + members.len() * nh * dicts.values[li].n);
            }
            if !groups.is_empty() {
                if qd_round.len() < *qd_off.last().unwrap() {
                    qd_round.resize(*qd_off.last().unwrap(), 0.0);
                }
                if z_round.len() < *z_off.last().unwrap() {
                    z_round.resize(*z_off.last().unwrap(), 0.0);
                }
                for (gi, (dicts, members)) in groups.iter().enumerate() {
                    let dk = &dicts.keys[li];
                    let rows = members.len() * nh;
                    if qg.len() < members.len() * qd {
                        qg.resize(members.len() * qd, 0.0);
                    }
                    for (mi, &bi) in members.iter().enumerate() {
                        qg[mi * qd..(mi + 1) * qd].copy_from_slice(&q[bi * qd..(bi + 1) * qd]);
                    }
                    par_matmul_bt(
                        &self.pool,
                        &mut qd_round[qd_off[gi]..qd_off[gi + 1]],
                        &qg[..rows * m],
                        &dk.atoms,
                        rows,
                        m,
                        dk.n,
                    );
                }
            }
            // Phase A — per-session cache traffic, fanned out across the
            // pool: each session is an independent shard (its own cache,
            // its own K/V/Q rows, its own attn row, its own z block), so
            // the per-session computation — and therefore the whole round —
            // is bitwise identical to the sequential loop. Fork-shared CSR
            // pages are only ever read (appends go to fork-private tails),
            // so sibling candidates decoding in the same round stay safe.
            // Shared-dictionary sessions score + softmax against their
            // precomputed qd rows and emit base value z-bins; the rest run
            // their plain attend.
            {
                let (kr, vr, qr): (&[f32], &[f32], &[f32]) = (&*k, &*v, &*q);
                let cache_ptr = SendPtr::new(caches.as_mut_ptr());
                let attn_ptr = SendPtr::new(attn.as_mut_ptr());
                let z_ptr = SendPtr::new(z_round.as_mut_ptr());
                let qd_round_r: &[f32] = qd_round;
                let (slot_r, groups_r) = (&slot, &groups);
                let (qd_off_r, z_off_r) = (&qd_off, &z_off);
                self.pool.parallel_for(bsz, move |bi| {
                    // SAFETY: shard bi exclusively owns caches[bi] and
                    // attn row bi.
                    let cache = unsafe { &mut *cache_ptr.get().add(bi) };
                    let attn_row =
                        unsafe { std::slice::from_raw_parts_mut(attn_ptr.get().add(bi * qd), qd) };
                    cache.append(li, &kr[bi * kvd..(bi + 1) * kvd], &vr[bi * kvd..(bi + 1) * kvd]);
                    let qrow = &qr[bi * qd..(bi + 1) * qd];
                    match slot_r[bi] {
                        Some((gi, mi)) => {
                            let nk = groups_r[gi].0.keys[li].n;
                            let nv = groups_r[gi].0.values[li].n;
                            let qd_s = &qd_round_r
                                [qd_off_r[gi] + mi * nh * nk..qd_off_r[gi] + (mi + 1) * nh * nk];
                            // SAFETY: session bi exclusively owns its z block.
                            let z_s = unsafe {
                                std::slice::from_raw_parts_mut(
                                    z_ptr.get().add(z_off_r[gi] + mi * nh * nv),
                                    nh * nv,
                                )
                            };
                            attn_row.fill(0.0);
                            cache.begin_shared_attend(li, qrow, qd_s, z_s);
                        }
                        None => cache.attend(li, qrow, attn_row),
                    }
                });
            }
            // Phase B — one streaming pass over each group's shared value
            // dictionary applies every member's base z-bins. Row-sharded:
            // each shard owns whole (member, head) output rows, and within
            // a shard atoms are visited in ascending order — per output
            // element this is exactly the per-session atoms·z order (zero
            // bins skipped, matching `attend`), so the result is bitwise
            // identical at every thread count.
            for (gi, (dicts, members)) in groups.iter().enumerate() {
                let dv = &dicts.values[li];
                let nv = dv.n;
                let rows = members.len() * nh;
                let z_g: &[f32] = &z_round[z_off[gi]..z_off[gi] + rows * nv];
                let members_r: &[usize] = members;
                let attn_ptr = SendPtr::new(attn.as_mut_ptr());
                let shards = self.pool.threads().min(rows).max(1);
                self.pool.parallel_for(shards, move |si| {
                    let (lo, hi) = (si * rows / shards, (si + 1) * rows / shards);
                    for n in 0..nv {
                        let atom = &dv.atoms[n * m..(n + 1) * m];
                        for r in lo..hi {
                            let zn = z_g[r * nv + n];
                            if zn != 0.0 {
                                let bi = members_r[r / nh];
                                let hh = r % nh;
                                // SAFETY: shard si exclusively owns output
                                // rows lo..hi (disjoint (bi, hh) pairs).
                                let oh = unsafe {
                                    std::slice::from_raw_parts_mut(
                                        attn_ptr.get().add(bi * qd + hh * m),
                                        m,
                                    )
                                };
                                axpy(oh, zn, atom);
                            }
                        }
                    }
                });
            }
            // Phase C — per-session remainder: adaptive extension atoms and
            // the recency buffer, in the same per-element order as the
            // per-session attend.
            if !groups.is_empty() {
                let cache_ptr = SendPtr::new(caches.as_mut_ptr());
                let attn_ptr = SendPtr::new(attn.as_mut_ptr());
                let slot_r: &[Option<(usize, usize)>] = &slot;
                self.pool.parallel_for(bsz, move |bi| {
                    if slot_r[bi].is_some() {
                        // SAFETY: shard bi exclusively owns caches[bi] and
                        // attn row bi.
                        let cache = unsafe { &mut *cache_ptr.get().add(bi) };
                        let attn_row = unsafe {
                            std::slice::from_raw_parts_mut(attn_ptr.get().add(bi * qd), qd)
                        };
                        cache.finish_shared_attend(li, attn_row);
                    }
                });
            }
            par_matmul_kmajor(&self.pool, proj, attn, &lw.wo, bsz, qd, d);
            for i in 0..bsz * d {
                x[i] += proj[i];
            }
            for bi in 0..bsz {
                rmsnorm(&mut h[bi * d..(bi + 1) * d], &x[bi * d..(bi + 1) * d], &lw.ln2, RMS_EPS);
            }
            par_matmul_kmajor(&self.pool, ff1, h, &lw.w1, bsz, d, cfg.d_ff);
            par_matmul_kmajor(&self.pool, ff3, h, &lw.w3, bsz, d, cfg.d_ff);
            for i in 0..bsz * cfg.d_ff {
                ff1[i] = silu(ff1[i]) * ff3[i];
            }
            par_matmul_kmajor(&self.pool, proj, ff1, &lw.w2, bsz, cfg.d_ff, d);
            for i in 0..bsz * d {
                x[i] += proj[i];
            }
        }
        for bi in 0..bsz {
            rmsnorm(&mut h[bi * d..(bi + 1) * d], &x[bi * d..(bi + 1) * d], &self.weights.lnf, RMS_EPS);
        }
        self.logits_batch(&h[..bsz * d], bsz)
    }

    /// Tied unembedding for a batch of rows: one streaming pass over the
    /// embedding matrix serves every session (row values identical to
    /// [`Engine::logits`] — each logit is the same single dot product),
    /// sharded by vocab blocks so each embedding row is read by exactly one
    /// shard.
    fn logits_batch(&self, hs: &[f32], bsz: usize) -> Vec<Vec<f32>> {
        let cfg = self.weights.cfg;
        let d = cfg.d_model;
        let vocab = cfg.vocab;
        let embed: &[f32] = &self.weights.embed;
        let mut out = vec![vec![0.0f32; vocab]; bsz];
        let shards = crate::tensor::col_shards(vocab, self.pool.threads(), 8);
        if shards == 1 || bsz * vocab * d < crate::tensor::PAR_MIN_MACS {
            // tiny unembedding: a pool launch costs more than it saves
            for vtok in 0..vocab {
                let erow = &embed[vtok * d..(vtok + 1) * d];
                for (bi, row) in out.iter_mut().enumerate() {
                    row[vtok] = dot(&hs[bi * d..(bi + 1) * d], erow);
                }
            }
            return out;
        }
        let rows: Vec<SendPtr<f32>> = out.iter_mut().map(|r| SendPtr::new(r.as_mut_ptr())).collect();
        self.pool.parallel_for(shards, |si| {
            let (lo, hi) = (si * vocab / shards, (si + 1) * vocab / shards);
            for vtok in lo..hi {
                let erow = &embed[vtok * d..(vtok + 1) * d];
                for (bi, rp) in rows.iter().enumerate() {
                    // SAFETY: shard si exclusively owns vocab slots lo..hi
                    // of every row.
                    unsafe { *rp.get().add(vtok) = dot(&hs[bi * d..(bi + 1) * d], erow) };
                }
            }
        });
        out
    }

    /// Tied unembedding: logits = h · embedᵀ, sharded by vocab blocks (each
    /// logit is one whole dot product, so thread count cannot change it).
    fn logits(&self, h: &[f32]) -> Vec<f32> {
        let cfg = self.weights.cfg;
        let d = cfg.d_model;
        let vocab = cfg.vocab;
        let embed: &[f32] = &self.weights.embed;
        let mut out = vec![0.0f32; vocab];
        let shards = crate::tensor::col_shards(vocab, self.pool.threads(), 8);
        if shards == 1 || vocab * d < crate::tensor::PAR_MIN_MACS {
            for (vtok, o) in out.iter_mut().enumerate() {
                *o = dot(h, &embed[vtok * d..(vtok + 1) * d]);
            }
            return out;
        }
        let out_ptr = SendPtr::new(out.as_mut_ptr());
        self.pool.parallel_for(shards, move |si| {
            let (lo, hi) = (si * vocab / shards, (si + 1) * vocab / shards);
            for vtok in lo..hi {
                // SAFETY: shard si exclusively owns vocab slots lo..hi.
                unsafe { *out_ptr.get().add(vtok) = dot(h, &embed[vtok * d..(vtok + 1) * d]) };
            }
        });
        out
    }

    /// Greedy generation: prefill the prompt, then decode up to `max_new`
    /// tokens, stopping after `stop` (which is included in the output).
    pub fn generate(
        &self,
        prompt: &[u32],
        max_new: usize,
        stop: Option<u32>,
        cache: &mut dyn KvCache,
    ) -> Vec<u32> {
        let logits = self.prefill(prompt, cache);
        let mut out = Vec::with_capacity(max_new);
        let mut next = argmax(&logits) as u32;
        let mut pos = prompt.len();
        for i in 0..max_new {
            out.push(next);
            // the last iteration's decode would produce a token we never
            // emit - skip it
            if Some(next) == stop || pos >= self.weights.cfg.max_seq || i + 1 == max_new {
                break;
            }
            let logits = self.decode_step(next, pos, cache);
            next = argmax(&logits) as u32;
            pos += 1;
        }
        out
    }

    /// Average next-token NLL (nats) of `tokens` under teacher forcing,
    /// decoding through `cache` — the language-modeling metric.
    pub fn nll(&self, tokens: &[u32], cache: &mut dyn KvCache) -> f64 {
        assert!(tokens.len() >= 2);
        let mut total = 0.0f64;
        let mut count = 0usize;
        let mut logits = self.prefill(&tokens[..1], cache);
        for (i, &target) in tokens.iter().enumerate().skip(1) {
            // log-softmax at the target
            let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 = logits.iter().map(|&l| (l - mx).exp()).sum::<f32>().ln() + mx;
            total += (lse - logits[target as usize]) as f64;
            count += 1;
            if i < tokens.len() - 1 {
                logits = self.decode_step(target, i, cache);
            }
        }
        total / count as f64
    }
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::cache::full::FullCache;
    pub use crate::model::testutil::tiny_weights;

    #[test]
    fn prefill_decode_consistency() {
        // Prefilling [a,b,c] then decoding d must equal prefilling [a,b,c,d]
        // (causality: the full cache path is exact).
        let eng = Engine::new(tiny_weights(1));
        let toks = [1u32, 4, 7, 2];
        let mut c1 = FullCache::new(eng.shape());
        let l_a = eng.prefill(&toks, &mut c1);
        let mut c2 = FullCache::new(eng.shape());
        let _ = eng.prefill(&toks[..3], &mut c2);
        let l_b = eng.decode_step(toks[3], 3, &mut c2);
        crate::util::prop::assert_close(&l_a, &l_b, 1e-4, "prefill≡decode").unwrap();
    }

    #[test]
    fn decode_batch_matches_decode_step_bitwise() {
        // Three sessions with different prompts and lengths: the batched
        // path must return the exact logits of three sequential steps.
        let eng = Engine::new(tiny_weights(9));
        let prompts: [&[u32]; 3] = [&[1, 4, 7], &[2, 3, 5, 8], &[9, 9]];
        let mut seq_caches: Vec<FullCache> = Vec::new();
        let mut bat_caches: Vec<FullCache> = Vec::new();
        let mut toks = Vec::new();
        let mut poss = Vec::new();
        for p in prompts {
            let mut c1 = FullCache::new(eng.shape());
            let l = eng.prefill(p, &mut c1);
            let mut c2 = FullCache::new(eng.shape());
            let _ = eng.prefill(p, &mut c2);
            seq_caches.push(c1);
            bat_caches.push(c2);
            toks.push(argmax(&l) as u32);
            poss.push(p.len());
        }
        for _round in 0..4 {
            let seq_logits: Vec<Vec<f32>> = (0..3)
                .map(|i| eng.decode_step(toks[i], poss[i], &mut seq_caches[i]))
                .collect();
            let mut refs: Vec<&mut dyn crate::cache::KvCache> =
                bat_caches.iter_mut().map(|c| c as &mut dyn crate::cache::KvCache).collect();
            let bat_logits = eng.decode_batch(&toks, &poss, &mut refs);
            assert_eq!(seq_logits, bat_logits, "batched logits diverged");
            for i in 0..3 {
                toks[i] = argmax(&bat_logits[i]) as u32;
                poss[i] += 1;
            }
        }
    }

    #[test]
    fn round_shared_qd_decode_matches_per_session_bitwise() {
        // The tentpole end-to-end parity: mixed backends (two plain lexico
        // sessions sharing one Arc<DictionarySet>, one adaptive lexico on
        // the same base dicts, one FullCache fallback) decoded with the
        // round-level shared-qd GEMM must produce logits bitwise identical
        // to the flag-off fan-out AND to per-session decode_step — at
        // T ∈ {1, 2, 4}.
        use crate::cache::lexico::{LexicoCache, LexicoConfig};
        use crate::dict::{Dictionary, DictionarySet};
        use crate::exec::ExecPool;
        let prompts: [&[u32]; 4] = [&[1, 4, 7], &[2, 3, 5, 8], &[9, 9, 3], &[5, 6]];
        for threads in [1usize, 2, 4] {
            let pool = Arc::new(ExecPool::new(threads));
            let mut eng_on = Engine::with_pool(tiny_weights(9), pool.clone());
            eng_on.set_round_shared_qd(true);
            let mut eng_off = Engine::with_pool(tiny_weights(9), pool.clone());
            eng_off.set_round_shared_qd(false);
            let shape = eng_on.shape();
            let dicts = Arc::new(DictionarySet {
                keys: (0..shape.n_layers)
                    .map(|i| Dictionary::random(shape.head_dim, 24, 300 + i as u64))
                    .collect(),
                values: (0..shape.n_layers)
                    .map(|i| Dictionary::random(shape.head_dim, 24, 400 + i as u64))
                    .collect(),
            });
            let mk_set = |eng: &Engine| -> (Vec<Box<dyn crate::cache::KvCache>>, Vec<u32>, Vec<usize>) {
                let lex = LexicoConfig { sparsity: 2, n_buffer: 4, ..Default::default() };
                let ada = LexicoConfig {
                    sparsity: 2,
                    n_buffer: 4,
                    adaptive: Some((8, 0.05)),
                    ..Default::default()
                };
                let mut caches: Vec<Box<dyn crate::cache::KvCache>> = vec![
                    Box::new(LexicoCache::new(shape, dicts.clone(), lex.clone())),
                    Box::new(LexicoCache::new(shape, dicts.clone(), lex)),
                    Box::new(LexicoCache::new(shape, dicts.clone(), ada)),
                    Box::new(FullCache::new(shape)),
                ];
                let mut toks = Vec::new();
                let mut poss = Vec::new();
                let rt = crate::runtime::CacheRuntime::from_env().with_pool(pool.clone());
                for (ci, p) in prompts.iter().enumerate() {
                    caches[ci].set_runtime(&rt);
                    let l = eng.prefill(p, &mut *caches[ci]);
                    toks.push(argmax(&l) as u32);
                    poss.push(p.len());
                }
                (caches, toks, poss)
            };
            let (mut on_caches, mut toks, mut poss) = mk_set(&eng_on);
            let (mut off_caches, toks_b, poss_b) = mk_set(&eng_off);
            let (mut step_caches, toks_c, poss_c) = mk_set(&eng_off);
            assert_eq!(toks, toks_b);
            assert_eq!(toks, toks_c);
            assert_eq!(poss, poss_b);
            assert_eq!(poss, poss_c);
            for round in 0..5 {
                let step_logits: Vec<Vec<f32>> = (0..prompts.len())
                    .map(|i| eng_off.decode_step(toks[i], poss[i], &mut *step_caches[i]))
                    .collect();
                let mut on_refs: Vec<&mut dyn crate::cache::KvCache> =
                    on_caches.iter_mut().map(|c| &mut **c).collect();
                let on_logits = eng_on.decode_batch(&toks, &poss, &mut on_refs);
                let mut off_refs: Vec<&mut dyn crate::cache::KvCache> =
                    off_caches.iter_mut().map(|c| &mut **c).collect();
                let off_logits = eng_off.decode_batch(&toks, &poss, &mut off_refs);
                assert_eq!(
                    on_logits, off_logits,
                    "T={threads} round={round}: shared-qd path diverged from fan-out"
                );
                assert_eq!(
                    on_logits, step_logits,
                    "T={threads} round={round}: shared-qd path diverged from decode_step"
                );
                for i in 0..prompts.len() {
                    toks[i] = argmax(&on_logits[i]) as u32;
                    poss[i] += 1;
                }
            }
        }
    }

    #[test]
    fn prefill_suffix_reproduces_cold_prefill_bitwise() {
        let eng = Engine::new(tiny_weights(12));
        let toks: Vec<u32> = vec![1, 4, 7, 2, 9, 3, 8, 5];
        let mut cold = FullCache::new(eng.shape());
        let l_cold = eng.prefill(&toks, &mut cold);

        let mut c_pref = FullCache::new(eng.shape());
        let (l_pref, state) = eng.prefill_capture(&toks[..5], &mut c_pref);
        // capture must not perturb the prefix prefill itself
        let mut c_plain = FullCache::new(eng.shape());
        assert_eq!(l_pref, eng.prefill(&toks[..5], &mut c_plain));
        assert_eq!(state.len(), 5);
        assert_eq!(state.logits, l_pref);
        assert!(state.bytes() > 0.0);

        let l_suf = eng.prefill_suffix(&state, &toks[5..], &mut c_pref);
        assert_eq!(l_cold, l_suf, "suffix prefill logits diverged from cold");
        // decode continuations must match bitwise too
        let t1 = argmax(&l_cold) as u32;
        let a = eng.decode_step(t1, toks.len(), &mut cold);
        let b = eng.decode_step(t1, toks.len(), &mut c_pref);
        assert_eq!(a, b, "post-suffix decode diverged");

        // empty suffix: stored logits, cache untouched
        let mut c0 = FullCache::new(eng.shape());
        let _ = eng.prefill(&toks[..5], &mut c0);
        let before = c0.tokens();
        assert_eq!(eng.prefill_suffix(&state, &[], &mut c0), state.logits);
        assert_eq!(c0.tokens(), before);
    }

    #[test]
    fn prefill_suffix_capture_extends_the_state() {
        let eng = Engine::new(tiny_weights(13));
        let toks: Vec<u32> = vec![2, 5, 8, 3, 6, 9, 4];
        let kvd = eng.shape().kv_dim();
        let mut c1 = FullCache::new(eng.shape());
        let (_, st1) = eng.prefill_capture(&toks[..4], &mut c1);
        let (l2, st2) = eng.prefill_suffix_capture(&st1, &toks[4..], &mut c1);
        assert_eq!(st2.tokens, toks);
        for li in 0..eng.shape().n_layers {
            assert_eq!(st2.ks[li].len(), toks.len() * kvd);
            assert_eq!(st2.vs[li].len(), toks.len() * kvd);
            // the extended state's prefix rows are exactly the old state's
            assert_eq!(&st2.ks[li][..4 * kvd], &st1.ks[li][..]);
        }
        // and it must equal a cold capture of the full prompt
        let mut c2 = FullCache::new(eng.shape());
        let (l_cold, st_cold) = eng.prefill_capture(&toks, &mut c2);
        assert_eq!(l2, l_cold);
        assert_eq!(st2.ks, st_cold.ks);
        assert_eq!(st2.vs, st_cold.vs);
    }

    #[test]
    fn prefill_chunk_reproduces_monolithic_prefill_bitwise() {
        // Any chunking of the prompt — including one token at a time —
        // must land the identical cache state and final logits.
        let eng = Engine::new(tiny_weights(21));
        let toks: Vec<u32> = vec![1, 4, 7, 2, 9, 3, 8, 5, 6, 2, 4, 1, 7];
        let mut cold = FullCache::new(eng.shape());
        let (l_cold, st_cold) = eng.prefill_capture(&toks, &mut cold);
        for chunk in [1usize, 3, 5, toks.len()] {
            let mut cache = FullCache::new(eng.shape());
            let mut state = PrefixState::empty(eng.shape().n_layers);
            let mut logits = Vec::new();
            for c in toks.chunks(chunk) {
                logits = eng.prefill_chunk(&mut state, c, &mut cache);
            }
            assert_eq!(logits, l_cold, "C={chunk}: final logits diverged");
            assert_eq!(state.tokens, st_cold.tokens, "C={chunk}");
            assert_eq!(state.ks, st_cold.ks, "C={chunk}: K rows diverged");
            assert_eq!(state.vs, st_cold.vs, "C={chunk}: V rows diverged");
            assert_eq!(state.logits, st_cold.logits, "C={chunk}");
            // the landed cache must continue bitwise like the cold one
            let t1 = argmax(&l_cold) as u32;
            let mut cold2 = cold.fork();
            let a = eng.decode_step(t1, toks.len(), &mut *cold2);
            let b = eng.decode_step(t1, toks.len(), &mut cache);
            assert_eq!(a, b, "C={chunk}: post-prefill decode diverged");
        }
    }

    #[test]
    fn prefill_chunk_empty_chunk_is_a_noop() {
        let eng = Engine::new(tiny_weights(22));
        let toks: Vec<u32> = vec![2, 5, 8, 3];
        let mut cache = FullCache::new(eng.shape());
        let mut state = PrefixState::empty(eng.shape().n_layers);
        let l = eng.prefill_chunk(&mut state, &toks, &mut cache);
        let before = cache.tokens();
        assert_eq!(eng.prefill_chunk(&mut state, &[], &mut cache), l);
        assert_eq!(cache.tokens(), before);
        assert_eq!(state.len(), toks.len());
    }

    #[test]
    fn prefill_chunk_resumes_a_captured_prefix() {
        // Chunked continuation from a prefix-cache entry's state must equal
        // the monolithic suffix resume (the batcher's prefix-hit path).
        let eng = Engine::new(tiny_weights(23));
        let toks: Vec<u32> = vec![1, 4, 7, 2, 9, 3, 8, 5, 6, 2];
        let mut c1 = FullCache::new(eng.shape());
        let (_, st) = eng.prefill_capture(&toks[..4], &mut c1);
        let l_mono = eng.prefill_suffix(&st, &toks[4..], &mut c1);

        let mut c2 = FullCache::new(eng.shape());
        let _ = eng.prefill(&toks[..4], &mut c2);
        let mut state = st.clone();
        let mut l_chunk = Vec::new();
        for c in toks[4..].chunks(2) {
            l_chunk = eng.prefill_chunk(&mut state, c, &mut c2);
        }
        assert_eq!(l_chunk, l_mono, "chunked suffix resume diverged");
        assert_eq!(state.tokens, toks);
    }

    #[test]
    fn decode_steps_accumulate_cache() {
        let eng = Engine::new(tiny_weights(2));
        let mut cache = FullCache::new(eng.shape());
        let out = eng.generate(&[1, 2, 3], 5, None, &mut cache);
        assert_eq!(out.len(), 5);
        assert_eq!(cache.tokens(), 3 + 4); // prompt + 4 decoded appends
        for &t in &out {
            assert!((t as usize) < eng.weights.cfg.vocab);
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let eng = Engine::new(tiny_weights(3));
        let mut c1 = FullCache::new(eng.shape());
        let mut c2 = FullCache::new(eng.shape());
        let a = eng.generate(&[5, 6], 8, None, &mut c1);
        let b = eng.generate(&[5, 6], 8, None, &mut c2);
        assert_eq!(a, b);
    }

    #[test]
    fn nll_is_finite_and_positive() {
        let eng = Engine::new(tiny_weights(4));
        let mut cache = FullCache::new(eng.shape());
        let nll = eng.nll(&[1, 2, 3, 4, 5, 6], &mut cache);
        assert!(nll.is_finite() && nll > 0.0, "{nll}");
        // random model ≈ uniform: nll near ln(vocab)
        let expect = (eng.weights.cfg.vocab as f64).ln();
        assert!((nll - expect).abs() < 2.0, "{nll} vs {expect}");
    }
}
