//! Forward passes: prefill (full-precision attention, per the paper's
//! protocol) and single-token decode through a pluggable [`KvCache`].

use crate::cache::{CacheShape, KvCache};
use crate::model::weights::Weights;
use crate::tensor::{argmax, dot, matmul, rmsnorm, silu, softmax};

const RMS_EPS: f32 = 1e-5;

/// Precomputed RoPE tables (split-half convention, matching the JAX model).
struct Rope {
    cos: Vec<f32>, // [max_seq][half]
    sin: Vec<f32>,
    half: usize,
}

impl Rope {
    fn new(head_dim: usize, max_seq: usize, base: f32) -> Self {
        let half = head_dim / 2;
        let mut cos = vec![0.0; max_seq * half];
        let mut sin = vec![0.0; max_seq * half];
        for p in 0..max_seq {
            for i in 0..half {
                let ang = p as f32 * base.powf(-(i as f32) / half as f32);
                cos[p * half + i] = ang.cos();
                sin[p * half + i] = ang.sin();
            }
        }
        Rope { cos, sin, half }
    }

    /// Rotate one head vector in place for position `pos`.
    #[inline]
    fn apply(&self, x: &mut [f32], pos: usize) {
        let h = self.half;
        let (c, s) = (&self.cos[pos * h..(pos + 1) * h], &self.sin[pos * h..(pos + 1) * h]);
        for i in 0..h {
            let (x1, x2) = (x[i], x[i + h]);
            x[i] = x1 * c[i] - x2 * s[i];
            x[i + h] = x1 * s[i] + x2 * c[i];
        }
    }
}

/// Scratch buffers so decode allocates nothing in steady state.
struct Scratch {
    x: Vec<f32>,
    h: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    proj: Vec<f32>,
    ff1: Vec<f32>,
    ff3: Vec<f32>,
}

/// The native engine: owns weights + RoPE tables; caches are passed in.
pub struct Engine {
    pub weights: Weights,
    rope: Rope,
    scratch: std::sync::Mutex<Scratch>,
}

/// How many trailing prompt queries are handed to the cache as the
/// observation window (SnapKV/PyramidKV); bounded by the prompt length.
pub const OBS_WINDOW: usize = 8;

impl Engine {
    pub fn new(weights: Weights) -> Self {
        let cfg = weights.cfg;
        let rope = Rope::new(cfg.head_dim, cfg.max_seq, 10000.0);
        let scratch = Scratch {
            x: vec![0.0; cfg.d_model],
            h: vec![0.0; cfg.d_model],
            q: vec![0.0; cfg.q_dim()],
            k: vec![0.0; cfg.kv_dim()],
            v: vec![0.0; cfg.kv_dim()],
            attn: vec![0.0; cfg.q_dim()],
            proj: vec![0.0; cfg.d_model],
            ff1: vec![0.0; cfg.d_ff],
            ff3: vec![0.0; cfg.d_ff],
        };
        Engine { weights, rope, scratch: std::sync::Mutex::new(scratch) }
    }

    pub fn shape(&self) -> CacheShape {
        let c = self.weights.cfg;
        CacheShape {
            n_layers: c.n_layers,
            n_heads: c.n_heads,
            n_kv_heads: c.n_kv_heads,
            head_dim: c.head_dim,
        }
    }

    /// Prefill: full causal attention in full precision over the prompt,
    /// handing each layer's K/V states (plus the last-`OBS_WINDOW` queries)
    /// to the cache. Returns the logits of the last prompt token.
    pub fn prefill(&self, tokens: &[u32], cache: &mut dyn KvCache) -> Vec<f32> {
        let cfg = self.weights.cfg;
        let t = tokens.len();
        assert!(t > 0 && t <= cfg.max_seq, "prompt length {t}");
        let d = cfg.d_model;
        let m = cfg.head_dim;
        let (qd, kvd) = (cfg.q_dim(), cfg.kv_dim());
        let scale = 1.0 / (m as f32).sqrt();

        let mut x = vec![0.0; t * d];
        for (ti, &tok) in tokens.iter().enumerate() {
            x[ti * d..(ti + 1) * d]
                .copy_from_slice(&self.weights.embed[tok as usize * d..(tok as usize + 1) * d]);
        }
        let mut h = vec![0.0; t * d];
        let mut q = vec![0.0; t * qd];
        let mut k = vec![0.0; t * kvd];
        let mut v = vec![0.0; t * kvd];
        let mut attn = vec![0.0; t * qd];
        let mut proj = vec![0.0; t * d];
        let mut scores = vec![0.0; t];
        let mut ff1 = vec![0.0; t * cfg.d_ff];
        let mut ff3 = vec![0.0; t * cfg.d_ff];

        for (li, lw) in self.weights.layers.iter().enumerate() {
            for ti in 0..t {
                rmsnorm(&mut h[ti * d..(ti + 1) * d], &x[ti * d..(ti + 1) * d], &lw.ln1, RMS_EPS);
            }
            matmul(&mut q, &h, &lw.wq, t, d, qd);
            matmul(&mut k, &h, &lw.wk, t, d, kvd);
            matmul(&mut v, &h, &lw.wv, t, d, kvd);
            for ti in 0..t {
                for hh in 0..cfg.n_heads {
                    self.rope.apply(&mut q[ti * qd + hh * m..ti * qd + (hh + 1) * m], ti);
                }
                for g in 0..cfg.n_kv_heads {
                    self.rope.apply(&mut k[ti * kvd + g * m..ti * kvd + (g + 1) * m], ti);
                }
            }
            // full-precision causal attention (paper: prefill attends in FP)
            attn.fill(0.0);
            for hh in 0..cfg.n_heads {
                let g = hh / cfg.group();
                for ti in 0..t {
                    let qrow = &q[ti * qd + hh * m..ti * qd + (hh + 1) * m];
                    for tj in 0..=ti {
                        scores[tj] =
                            dot(qrow, &k[tj * kvd + g * m..tj * kvd + (g + 1) * m]) * scale;
                    }
                    softmax(&mut scores[..=ti]);
                    let orow = &mut attn[ti * qd + hh * m..ti * qd + (hh + 1) * m];
                    for tj in 0..=ti {
                        crate::tensor::axpy(
                            orow,
                            scores[tj],
                            &v[tj * kvd + g * m..tj * kvd + (g + 1) * m],
                        );
                    }
                }
            }
            // hand the layer's KV states + observation-window queries over
            let w = OBS_WINDOW.min(t);
            cache.ingest_prefill(li, &k, &v, t, &q[(t - w) * qd..], w);

            matmul(&mut proj, &attn, &lw.wo, t, qd, d);
            for i in 0..t * d {
                x[i] += proj[i];
            }
            for ti in 0..t {
                rmsnorm(&mut h[ti * d..(ti + 1) * d], &x[ti * d..(ti + 1) * d], &lw.ln2, RMS_EPS);
            }
            matmul(&mut ff1, &h, &lw.w1, t, d, cfg.d_ff);
            matmul(&mut ff3, &h, &lw.w3, t, d, cfg.d_ff);
            for i in 0..t * cfg.d_ff {
                ff1[i] = silu(ff1[i]) * ff3[i];
            }
            matmul(&mut proj, &ff1, &lw.w2, t, cfg.d_ff, d);
            for i in 0..t * d {
                x[i] += proj[i];
            }
        }
        // logits of the last token only
        let last = &x[(t - 1) * d..t * d];
        let mut hn = vec![0.0; d];
        rmsnorm(&mut hn, last, &self.weights.lnf, RMS_EPS);
        self.logits(&hn)
    }

    /// One decode step: token at absolute position `pos` (0-based).
    /// The cache must already hold positions `0..pos`.
    pub fn decode_step(&self, token: u32, pos: usize, cache: &mut dyn KvCache) -> Vec<f32> {
        let cfg = self.weights.cfg;
        assert!(pos < cfg.max_seq, "position {pos} ≥ max_seq");
        let d = cfg.d_model;
        let m = cfg.head_dim;
        let (qd, kvd) = (cfg.q_dim(), cfg.kv_dim());
        let mut s = self.scratch.lock().unwrap();
        let s = &mut *s;
        s.x.copy_from_slice(&self.weights.embed[token as usize * d..(token as usize + 1) * d]);

        for (li, lw) in self.weights.layers.iter().enumerate() {
            rmsnorm(&mut s.h, &s.x, &lw.ln1, RMS_EPS);
            matmul(&mut s.q, &s.h, &lw.wq, 1, d, qd);
            matmul(&mut s.k, &s.h, &lw.wk, 1, d, kvd);
            matmul(&mut s.v, &s.h, &lw.wv, 1, d, kvd);
            for hh in 0..cfg.n_heads {
                self.rope.apply(&mut s.q[hh * m..(hh + 1) * m], pos);
            }
            for g in 0..cfg.n_kv_heads {
                self.rope.apply(&mut s.k[g * m..(g + 1) * m], pos);
            }
            cache.append(li, &s.k, &s.v);
            cache.attend(li, &s.q, &mut s.attn);
            matmul(&mut s.proj, &s.attn, &lw.wo, 1, qd, d);
            for i in 0..d {
                s.x[i] += s.proj[i];
            }
            rmsnorm(&mut s.h, &s.x, &lw.ln2, RMS_EPS);
            matmul(&mut s.ff1, &s.h, &lw.w1, 1, d, cfg.d_ff);
            matmul(&mut s.ff3, &s.h, &lw.w3, 1, d, cfg.d_ff);
            for i in 0..cfg.d_ff {
                s.ff1[i] = silu(s.ff1[i]) * s.ff3[i];
            }
            matmul(&mut s.proj, &s.ff1, &lw.w2, 1, cfg.d_ff, d);
            for i in 0..d {
                s.x[i] += s.proj[i];
            }
        }
        rmsnorm(&mut s.h, &s.x, &self.weights.lnf, RMS_EPS);
        self.logits(&s.h)
    }

    /// Tied unembedding: logits = h · embedᵀ.
    fn logits(&self, h: &[f32]) -> Vec<f32> {
        let cfg = self.weights.cfg;
        let d = cfg.d_model;
        (0..cfg.vocab)
            .map(|v| dot(h, &self.weights.embed[v * d..(v + 1) * d]))
            .collect()
    }

    /// Greedy generation: prefill the prompt, then decode up to `max_new`
    /// tokens, stopping after `stop` (which is included in the output).
    pub fn generate(
        &self,
        prompt: &[u32],
        max_new: usize,
        stop: Option<u32>,
        cache: &mut dyn KvCache,
    ) -> Vec<u32> {
        let logits = self.prefill(prompt, cache);
        let mut out = Vec::with_capacity(max_new);
        let mut next = argmax(&logits) as u32;
        let mut pos = prompt.len();
        for i in 0..max_new {
            out.push(next);
            // the last iteration's decode would produce a token we never
            // emit - skip it
            if Some(next) == stop || pos >= self.weights.cfg.max_seq || i + 1 == max_new {
                break;
            }
            let logits = self.decode_step(next, pos, cache);
            next = argmax(&logits) as u32;
            pos += 1;
        }
        out
    }

    /// Average next-token NLL (nats) of `tokens` under teacher forcing,
    /// decoding through `cache` — the language-modeling metric.
    pub fn nll(&self, tokens: &[u32], cache: &mut dyn KvCache) -> f64 {
        assert!(tokens.len() >= 2);
        let mut total = 0.0f64;
        let mut count = 0usize;
        let mut logits = self.prefill(&tokens[..1], cache);
        for (i, &target) in tokens.iter().enumerate().skip(1) {
            // log-softmax at the target
            let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 = logits.iter().map(|&l| (l - mx).exp()).sum::<f32>().ln() + mx;
            total += (lse - logits[target as usize]) as f64;
            count += 1;
            if i < tokens.len() - 1 {
                logits = self.decode_step(target, i, cache);
            }
        }
        total / count as f64
    }
}

#[cfg(test)]
pub mod tests {
    use super::*;
    use crate::cache::full::FullCache;
    pub use crate::model::testutil::tiny_weights;

    #[test]
    fn prefill_decode_consistency() {
        // Prefilling [a,b,c] then decoding d must equal prefilling [a,b,c,d]
        // (causality: the full cache path is exact).
        let eng = Engine::new(tiny_weights(1));
        let toks = [1u32, 4, 7, 2];
        let mut c1 = FullCache::new(eng.shape());
        let l_a = eng.prefill(&toks, &mut c1);
        let mut c2 = FullCache::new(eng.shape());
        let _ = eng.prefill(&toks[..3], &mut c2);
        let l_b = eng.decode_step(toks[3], 3, &mut c2);
        crate::util::prop::assert_close(&l_a, &l_b, 1e-4, "prefill≡decode").unwrap();
    }

    #[test]
    fn decode_steps_accumulate_cache() {
        let eng = Engine::new(tiny_weights(2));
        let mut cache = FullCache::new(eng.shape());
        let out = eng.generate(&[1, 2, 3], 5, None, &mut cache);
        assert_eq!(out.len(), 5);
        assert_eq!(cache.tokens(), 3 + 4); // prompt + 4 decoded appends
        for &t in &out {
            assert!((t as usize) < eng.weights.cfg.vocab);
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let eng = Engine::new(tiny_weights(3));
        let mut c1 = FullCache::new(eng.shape());
        let mut c2 = FullCache::new(eng.shape());
        let a = eng.generate(&[5, 6], 8, None, &mut c1);
        let b = eng.generate(&[5, 6], 8, None, &mut c2);
        assert_eq!(a, b);
    }

    #[test]
    fn nll_is_finite_and_positive() {
        let eng = Engine::new(tiny_weights(4));
        let mut cache = FullCache::new(eng.shape());
        let nll = eng.nll(&[1, 2, 3, 4, 5, 6], &mut cache);
        assert!(nll.is_finite() && nll > 0.0, "{nll}");
        // random model ≈ uniform: nll near ln(vocab)
        let expect = (eng.weights.cfg.vocab as f64).ln();
        assert!((nll - expect).abs() < 2.0, "{nll} vs {expect}");
    }
}
