//! The native transformer inference engine (GQA + RoPE + RMSNorm + SwiGLU),
//! bit-compatible with the JAX model in `python/compile/model.py` and fed by
//! the same `artifacts/model_*.bin` weights.

pub mod engine;
pub mod testutil;
pub mod weights;

pub use engine::{Engine, PrefixState};
pub use weights::{ModelConfig, Weights};
