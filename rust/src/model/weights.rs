//! LXMW weight-file reader (written by `python/compile/aot.py::save_model_bin`).
//!
//! Format (little-endian):
//!   magic "LXMW" | u32 version=1
//!   u32 ×8: n_layers d_model n_heads n_kv_heads head_dim d_ff vocab max_seq
//!   u32 n_tensors, then per tensor:
//!     u32 name_len | name | u32 rank | u32 dims[rank] | f32 data

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::Read;
use std::path::Path;

/// Architecture hyperparameters (mirrors `python/compile/model.py::ModelConfig`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub max_seq: usize,
}

impl ModelConfig {
    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }
    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }
    /// Query heads per kv head (GQA group size).
    pub fn group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }
}

/// One transformer layer's weights (all row-major, shapes as in model.py).
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub ln1: Vec<f32>,       // [d]
    pub wq: Vec<f32>,        // [d, H*m]
    pub wk: Vec<f32>,        // [d, KV*m]
    pub wv: Vec<f32>,        // [d, KV*m]
    pub wo: Vec<f32>,        // [H*m, d]
    pub ln2: Vec<f32>,       // [d]
    pub w1: Vec<f32>,        // [d, ff]
    pub w3: Vec<f32>,        // [d, ff]
    pub w2: Vec<f32>,        // [ff, d]
}

/// Full model weights. The unembedding is tied to `embed`.
#[derive(Clone, Debug)]
pub struct Weights {
    pub cfg: ModelConfig,
    pub embed: Vec<f32>, // [vocab, d]
    pub layers: Vec<LayerWeights>,
    pub lnf: Vec<f32>,   // [d]
    /// Flat name → tensor map kept for the PJRT runtime (manifest order).
    pub by_name: BTreeMap<String, (Vec<usize>, Vec<f32>)>,
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

impl Weights {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"LXMW" {
            bail!("{}: bad magic {:?}", path.display(), magic);
        }
        let ver = read_u32(&mut f)?;
        if ver != 1 {
            bail!("unsupported LXMW version {ver}");
        }
        let cfg = ModelConfig {
            n_layers: read_u32(&mut f)? as usize,
            d_model: read_u32(&mut f)? as usize,
            n_heads: read_u32(&mut f)? as usize,
            n_kv_heads: read_u32(&mut f)? as usize,
            head_dim: read_u32(&mut f)? as usize,
            d_ff: read_u32(&mut f)? as usize,
            vocab: read_u32(&mut f)? as usize,
            max_seq: read_u32(&mut f)? as usize,
        };
        let n_tensors = read_u32(&mut f)? as usize;
        let mut by_name = BTreeMap::new();
        for _ in 0..n_tensors {
            let name_len = read_u32(&mut f)? as usize;
            let mut nb = vec![0u8; name_len];
            f.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)?;
            let rank = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u32(&mut f)? as usize);
            }
            let n: usize = shape.iter().product();
            let data = read_f32s(&mut f, n)?;
            by_name.insert(name, (shape, data));
        }
        Self::assemble(cfg, by_name)
    }

    fn assemble(cfg: ModelConfig, by_name: BTreeMap<String, (Vec<usize>, Vec<f32>)>) -> Result<Self> {
        let get = |name: &str, want: &[usize]| -> Result<Vec<f32>> {
            let (shape, data) = by_name
                .get(name)
                .with_context(|| format!("missing tensor {name}"))?;
            if shape != want {
                bail!("tensor {name}: shape {shape:?}, expected {want:?}");
            }
            Ok(data.clone())
        };
        let d = cfg.d_model;
        let embed = get("embed", &[cfg.vocab, d])?;
        let lnf = get("lnf", &[d])?;
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = format!("layer{i}.");
            layers.push(LayerWeights {
                ln1: get(&format!("{p}ln1"), &[d])?,
                wq: get(&format!("{p}wq"), &[d, cfg.q_dim()])?,
                wk: get(&format!("{p}wk"), &[d, cfg.kv_dim()])?,
                wv: get(&format!("{p}wv"), &[d, cfg.kv_dim()])?,
                wo: get(&format!("{p}wo"), &[cfg.q_dim(), d])?,
                ln2: get(&format!("{p}ln2"), &[d])?,
                w1: get(&format!("{p}w1"), &[d, cfg.d_ff])?,
                w3: get(&format!("{p}w3"), &[d, cfg.d_ff])?,
                w2: get(&format!("{p}w2"), &[cfg.d_ff, d])?,
            });
        }
        Ok(Weights { cfg, embed, layers, lnf, by_name })
    }

    /// Fake-quantize every weight matrix to int4 (group size `g` along the
    /// input dim) — the Fig. 5 "weights quantized to 4 bits" setting.
    pub fn fake_quantize_int4(&mut self, g: usize) {
        let quant = |w: &mut Vec<f32>| crate::quant::fake_quant_rows(w, g, 4);
        for l in &mut self.layers {
            quant(&mut l.wq);
            quant(&mut l.wk);
            quant(&mut l.wv);
            quant(&mut l.wo);
            quant(&mut l.w1);
            quant(&mut l.w3);
            quant(&mut l.w2);
        }
        quant(&mut self.embed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny LXMW blob in memory and parse it.
    fn write_tensor(buf: &mut Vec<u8>, name: &str, shape: &[usize], data: &[f32]) {
        buf.extend((name.len() as u32).to_le_bytes());
        buf.extend(name.as_bytes());
        buf.extend((shape.len() as u32).to_le_bytes());
        for &s in shape {
            buf.extend((s as u32).to_le_bytes());
        }
        for &x in data {
            buf.extend(x.to_le_bytes());
        }
    }

    #[test]
    fn roundtrip_tiny_file() {
        let cfg = ModelConfig {
            n_layers: 1, d_model: 4, n_heads: 2, n_kv_heads: 1,
            head_dim: 2, d_ff: 8, vocab: 5, max_seq: 16,
        };
        let mut buf = Vec::new();
        buf.extend(b"LXMW");
        for v in [1u32, 1, 4, 2, 1, 2, 8, 5, 16] {
            buf.extend(v.to_le_bytes());
        }
        let names: Vec<(String, Vec<usize>)> = vec![
            ("embed".into(), vec![5, 4]),
            ("layer0.ln1".into(), vec![4]),
            ("layer0.wq".into(), vec![4, 4]),
            ("layer0.wk".into(), vec![4, 2]),
            ("layer0.wv".into(), vec![4, 2]),
            ("layer0.wo".into(), vec![4, 4]),
            ("layer0.ln2".into(), vec![4]),
            ("layer0.w1".into(), vec![4, 8]),
            ("layer0.w3".into(), vec![4, 8]),
            ("layer0.w2".into(), vec![8, 4]),
            ("lnf".into(), vec![4]),
        ];
        buf.extend((names.len() as u32).to_le_bytes());
        for (name, shape) in &names {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
            write_tensor(&mut buf, name, shape, &data);
        }
        let dir = std::env::temp_dir().join("lexico_test_lxmw.bin");
        std::fs::write(&dir, &buf).unwrap();
        let w = Weights::load(&dir).unwrap();
        assert_eq!(w.cfg, cfg);
        assert_eq!(w.layers.len(), 1);
        assert_eq!(w.embed.len(), 20);
        assert!((w.embed[3] - 0.3).abs() < 1e-6);
        std::fs::remove_file(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("lexico_test_badmagic.bin");
        std::fs::write(&dir, b"NOPE").unwrap();
        assert!(Weights::load(&dir).is_err());
        std::fs::remove_file(&dir).ok();
    }
}
