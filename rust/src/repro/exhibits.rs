//! Per-exhibit drivers. Each reproduces one table or figure of the paper
//! (shape, not absolute numbers — see DESIGN.md §1 for the substitutions)
//! and records paper-vs-measured rows in `reports/`.

use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use super::ReproOpts;

use crate::cache::full::FullCache;
use crate::cache::lexico::{LexicoCache, LexicoConfig};
use crate::dict::{DictionarySet, SaePair};
use crate::eval::{evaluate, EvalConfig, EvalResult};
use crate::model::{Engine, Weights};
use crate::omp::{omp_encode_alloc, rel_error};
use crate::tasks::{self, Task};
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// Shared plumbing
// ---------------------------------------------------------------------------

pub fn load_engine(artifacts: &Path, size: &str) -> Result<Engine> {
    let w = Weights::load(artifacts.join(format!("model_{size}.bin")))?;
    Ok(Engine::new(w))
}

pub fn load_dicts(artifacts: &Path, size: &str, n: usize) -> Result<Arc<DictionarySet>> {
    Ok(Arc::new(DictionarySet::load(
        artifacts.join(format!("dict_{size}_N{n}.bin")),
    )?))
}

fn write_report(opts: &ReproOpts, name: &str, body: Json) -> Result<()> {
    let path = opts.reports.join(format!("{name}.json"));
    std::fs::write(&path, body.to_string())?;
    println!("[report] {}", path.display());
    Ok(())
}

fn result_json(r: &EvalResult) -> Json {
    let agree = if r.agree.is_nan() { Json::Null } else { json::num(r.agree) };
    json::obj(vec![
        ("method", json::s(&r.method)),
        ("task", json::s(r.task)),
        ("kv_pct", json::num(100.0 * r.kv_ratio)),
        ("score", json::num(r.score)),
        ("agree_pct", agree),
        ("n", json::num(r.n as f64)),
    ])
}

fn print_header() {
    println!(
        "{:<34} {:>10} {:>9} {:>9} {:>5}",
        "method", "task", "KV size", "score", "agree"
    );
}

/// Run a list of method specs over a list of tasks; print + collect.
fn sweep(
    engine: &Engine,
    dicts: Option<Arc<DictionarySet>>,
    specs: &[String],
    suite: &[Task],
    n: usize,
    seed: u64,
) -> Result<Vec<EvalResult>> {
    let mut out = Vec::new();
    for spec in specs {
        for &task in suite {
            let r = evaluate(engine, dicts.clone(), spec, &EvalConfig::new(task, n, seed))?;
            println!("{}", crate::eval::format_row(&r));
            out.push(r);
        }
    }
    Ok(out)
}

fn samples(opts: &ReproOpts, full: usize) -> usize {
    if opts.fast {
        (full / 5).max(4)
    } else {
        full
    }
}

/// Default buffer size (scaled from the paper's n_b=128 at ~3.6k contexts to
/// our ~250-token contexts).
const NB: usize = 32;

/// Calibrate the Lexico sparsity whose measured KV ratio is closest to a
/// target (the paper's "s is set to match the KV size of the baseline").
fn match_sparsity(
    engine: &Engine,
    dicts: &Arc<DictionarySet>,
    task: Task,
    target: f64,
    seed: u64,
) -> Result<usize> {
    let mut best = (1usize, f64::INFINITY);
    for s in 1..=8 {
        let spec = format!("lexico:s={s},nb={NB}");
        let r = evaluate(
            engine,
            Some(dicts.clone()),
            &spec,
            &EvalConfig::new(task, 2, seed),
        )?;
        let d = (r.kv_ratio - target).abs();
        if d < best.1 {
            best = (s, d);
        }
    }
    Ok(best.0)
}

// ---------------------------------------------------------------------------
// Fig 1 — KV size vs GSM8K-substitute score, 3 model scales, all methods
// ---------------------------------------------------------------------------

pub fn fig1(opts: &ReproOpts) -> Result<()> {
    println!("Fig 1: memory vs performance across model scales (arith ≙ GSM8K)\n");
    let n = samples(opts, 60);
    let mut rows = Vec::new();
    for size in ["S", "M", "L"] {
        let engine = load_engine(&opts.artifacts, size)?;
        let dicts = load_dicts(&opts.artifacts, size, 1024)?;
        println!("--- model {size} ({} params) ---",
                 engine.weights.by_name.values().map(|(s, _)| s.iter().product::<usize>()).sum::<usize>());
        print_header();
        let mut specs = vec!["full".to_string()];
        for s in [2usize, 3, 4, 6, 8] {
            specs.push(format!("lexico:s={s},nb={NB}"));
        }
        for bits in [2, 4] {
            specs.push(format!("kivi:bits={bits},g=16,nb=16"));
            specs.push(format!("pertoken:bits={bits},g=16,nb=4"));
        }
        specs.push("zipcache:hi=4,lo=2,g=16,frac=0.2,nb=16".into());
        for cap in [24usize, 48, 96] {
            specs.push(format!("snapkv:cap={cap},win=8"));
            specs.push(format!("pyramidkv:cap={cap},win=8"));
        }
        let rs = sweep(&engine, Some(dicts), &specs, &[Task::Arith], n, 100 + size.len() as u64)?;
        for r in rs {
            rows.push(json::obj(vec![
                ("model", json::s(size)),
                ("row", result_json(&r)),
            ]));
        }
    }
    write_report(opts, "fig1", json::obj(vec![
        ("exhibit", json::s("fig1")),
        ("task", json::s("arith (GSM8K substitute)")),
        ("rows", json::arr(rows)),
    ]))
}

// ---------------------------------------------------------------------------
// Fig 3 — key clustering across inputs
// ---------------------------------------------------------------------------

pub fn fig3(opts: &ReproOpts) -> Result<()> {
    println!("Fig 3: pairwise cosine structure of keys (within & across inputs)\n");
    let engine = load_engine(&opts.artifacts, "M")?;
    let layer = engine.shape().n_layers / 2;
    let (st, cross, cross_rand) = crate::eval::keygeom::fig3(&engine, layer, 42)?;
    println!("layer {layer}: n={} keys", st.n);
    println!("mean |cos|  (all pairs)          : {:.3}", st.mean_abs_all);
    println!("mean |cos|  (sorted near-diag)   : {:.3}  ← cluster blocks", st.mean_abs_band);
    println!("frac keys with NN cos > 0.9      : {:.3}", st.frac_nn_above_09);
    println!("cross-input match frac (cos>0.8) : {:.3}  ← clusters recur across inputs", cross);
    println!("  vs random-vector control       : {:.3}", cross_rand);
    write_report(opts, "fig3", json::obj(vec![
        ("exhibit", json::s("fig3")),
        ("layer", json::num(layer as f64)),
        ("n_keys", json::num(st.n as f64)),
        ("mean_abs_all", json::num(st.mean_abs_all)),
        ("mean_abs_band", json::num(st.mean_abs_band)),
        ("frac_nn_above_09", json::num(st.frac_nn_above_09)),
        ("cross_match", json::num(cross)),
        ("cross_match_random_control", json::num(cross_rand)),
    ]))
}

// ---------------------------------------------------------------------------
// Table 1 — reconstruction error: Lexico vs SAE vs random dictionaries
// ---------------------------------------------------------------------------

/// Collect mid-layer K and V vectors from engine runs over a corpus family.
fn collect_kv_vectors(
    engine: &Engine,
    corpus: &str,
    seed: u64,
    n_tokens: usize,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    let mut rng = Rng::new(seed);
    let layer = engine.shape().n_layers / 2;
    let shape = engine.shape();
    let (kvd, m) = (shape.kv_dim(), shape.head_dim);
    let mut ks = Vec::new();
    let mut vs = Vec::new();
    while ks.len() < n_tokens {
        let text = match corpus {
            "prose" => tasks::gen_lm_text(&mut rng, 200),
            "arith" => {
                let mut t = String::new();
                for _ in 0..6 {
                    let steps = 3 + rng.below(4);
                    let e = tasks::gen_arith_example(&mut rng, steps);
                    t.push_str(&e.prompt);
                    t.push_str(&e.answer);
                    t.push('\n');
                }
                t
            }
            "retrieval" => {
                let pairs = 20 + rng.below(10);
                let e = tasks::gen_needle(&mut rng, pairs);
                format!("{}{}", e.prompt, e.answer)
            }
            _ => {
                let a = tasks::gen_sort(&mut rng, 5);
                let b = tasks::gen_copy(&mut rng, 20);
                format!("{}{}\n{}{}", a.prompt, a.answer, b.prompt, b.answer)
            }
        };
        let mut ids = vec![tasks::BOS];
        ids.extend(tasks::encode(&text));
        ids.truncate(engine.weights.cfg.max_seq - 1);
        let mut cache = FullCache::new(shape);
        let _ = engine.prefill(&ids, &mut cache);
        let kd = cache.keys(layer);
        let t = kd.len() / kvd;
        // also need values: FullCache only exposes keys; re-derive via a
        // second accessor — use both kv heads of keys, and values via the
        // values accessor below.
        for g in 0..shape.n_kv_heads {
            for ti in 0..t {
                ks.push(kd[ti * kvd + g * m..ti * kvd + (g + 1) * m].to_vec());
            }
        }
        let vd = cache.values(layer);
        for g in 0..shape.n_kv_heads {
            for ti in 0..t {
                vs.push(vd[ti * kvd + g * m..ti * kvd + (g + 1) * m].to_vec());
            }
        }
    }
    ks.truncate(n_tokens);
    vs.truncate(n_tokens);
    (ks, vs)
}

/// Public KV collection used by `lexico train-dict` (prose corpus).
pub fn collect_kv_for_training(
    engine: &Engine,
    seed: u64,
    n: usize,
) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
    collect_kv_vectors(engine, "prose", seed, n)
}

pub fn table1(opts: &ReproOpts) -> Result<()> {
    println!("Table 1: relative reconstruction error by dictionary type\n");
    let engine = load_engine(&opts.artifacts, "M")?;
    let dicts = load_dicts(&opts.artifacts, "M", 1024)?;
    let sae = SaePair::load(opts.artifacts.join("sae_M_N1024.bin"))
        .context("sae_M_N1024.bin (rebuild artifacts)")?;
    let layer = engine.shape().n_layers / 2;
    let rand = crate::dict::Dictionary::random(engine.shape().head_dim, 1024, 777);
    let s = 8usize; // paper: dictionary-training sparsity (m/4)
    let n_vecs = samples(opts, 600);

    println!(
        "{:<12} {:>16} {:>22} {:>22}",
        "corpus", "Lexico", "Sparse Autoencoder", "Random Dictionaries"
    );
    let mut rows = Vec::new();
    for corpus in ["prose", "arith", "retrieval", "mixed"] {
        let (ks, vs) = collect_kv_vectors(&engine, corpus, 0xC0 ^ corpus.len() as u64, n_vecs / 2);
        let mut errs_lex = Vec::new();
        let mut errs_sae = Vec::new();
        let mut errs_rand = Vec::new();
        for (vecs, is_key) in [(&ks, true), (&vs, false)] {
            let dict = if is_key { &dicts.keys[layer] } else { &dicts.values[layer] };
            for x in vecs.iter() {
                let c = omp_encode_alloc(&dict.atoms, dict.n, dict.m, x, s, 0.0);
                errs_lex.push(rel_error(&dict.atoms, dict.m, x, &c) as f64);
                errs_sae.push(sae.rel_error(x, s, is_key) as f64);
                let cr = omp_encode_alloc(&rand.atoms, rand.n, rand.m, x, s, 0.0);
                errs_rand.push(rel_error(&rand.atoms, rand.m, x, &cr) as f64);
            }
        }
        let ms = |v: &[f64]| {
            let mean = v.iter().sum::<f64>() / v.len() as f64;
            let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / v.len() as f64;
            (mean, var.sqrt())
        };
        let (ml, sl) = ms(&errs_lex);
        let (msae, ssae) = ms(&errs_sae);
        let (mr, sr) = ms(&errs_rand);
        println!(
            "{corpus:<12} {ml:>8.3} ± {sl:<5.3} {msae:>14.3} ± {ssae:<5.3} {mr:>14.3} ± {sr:<5.3}"
        );
        rows.push(json::obj(vec![
            ("corpus", json::s(corpus)),
            ("lexico", json::arr(vec![json::num(ml), json::num(sl)])),
            ("sae", json::arr(vec![json::num(msae), json::num(ssae)])),
            ("random", json::arr(vec![json::num(mr), json::num(sr)])),
        ]));
    }
    write_report(opts, "table1", json::obj(vec![
        ("exhibit", json::s("table1")),
        ("sparsity", json::num(s as f64)),
        ("rows", json::arr(rows)),
    ]))
}

// ---------------------------------------------------------------------------
// Table 2 — LongBench substitute at matched KV sizes (M + L models)
// ---------------------------------------------------------------------------

const LONG_SUITE: [Task; 4] = [Task::Needle, Task::Copy, Task::Sort, Task::Lm];

pub fn table2(opts: &ReproOpts) -> Result<()> {
    println!("Table 2: long-context suite at matched KV sizes\n");
    let n = samples(opts, 50);
    let mut rows = Vec::new();
    for size in ["M", "L"] {
        let engine = load_engine(&opts.artifacts, size)?;
        let dicts = load_dicts(&opts.artifacts, size, 1024)?;
        println!("--- model {size} ---");
        print_header();
        // measure the KIVI operating points first, then match Lexico's s
        let kivi4 = format!("kivi:bits=4,g=16,nb={NB}");
        let kivi2 = format!("kivi:bits=2,g=16,nb={NB}");
        let r4 = evaluate(&engine, None, &kivi4, &EvalConfig::new(Task::Needle, 2, 7))?;
        let r2 = evaluate(&engine, None, &kivi2, &EvalConfig::new(Task::Needle, 2, 7))?;
        let s4 = match_sparsity(&engine, &dicts, Task::Needle, r4.kv_ratio, 7)?;
        let s2 = match_sparsity(&engine, &dicts, Task::Needle, r2.kv_ratio, 7)?;
        let specs = vec![
            "full".to_string(),
            kivi4,
            format!("lexico:s={s4},nb={NB}"),
            kivi2,
            format!("lexico:s={s2},nb={NB}"),
            format!("lexico:s=2,nb={NB}"), // beyond-2-bit regime
        ];
        let rs = sweep(&engine, Some(dicts), &specs, &LONG_SUITE, n, 200)?;
        for r in rs {
            rows.push(json::obj(vec![("model", json::s(size)), ("row", result_json(&r))]));
        }
    }
    write_report(opts, "table2", json::obj(vec![
        ("exhibit", json::s("table2")),
        ("rows", json::arr(rows)),
    ]))
}

// ---------------------------------------------------------------------------
// Table 3 — GSM8K substitute at matched KV sizes (M + L models)
// ---------------------------------------------------------------------------

pub fn table3(opts: &ReproOpts) -> Result<()> {
    println!("Table 3: arith (GSM8K substitute) at matched KV sizes\n");
    let n = samples(opts, 80);
    let mut rows = Vec::new();
    for size in ["M", "L"] {
        let engine = load_engine(&opts.artifacts, size)?;
        let dicts = load_dicts(&opts.artifacts, size, 1024)?;
        println!("--- model {size} ---");
        print_header();
        let kivi4 = "kivi:bits=4,g=16,nb=16".to_string();
        let kivi2 = "kivi:bits=2,g=16,nb=16".to_string();
        let r4 = evaluate(&engine, None, &kivi4, &EvalConfig::new(Task::Arith, 2, 11))?;
        let r2 = evaluate(&engine, None, &kivi2, &EvalConfig::new(Task::Arith, 2, 11))?;
        let s4 = match_sparsity(&engine, &dicts, Task::Arith, r4.kv_ratio, 11)?;
        let s2 = match_sparsity(&engine, &dicts, Task::Arith, r2.kv_ratio, 11)?;
        let specs = vec![
            "full".to_string(),
            kivi4,
            format!("lexico:s={s4},nb={NB}"),
            kivi2,
            format!("lexico:s={s2},nb={NB}"),
            format!("lexico:s=1,nb={NB}"), // the paper's s=4 extreme point
        ];
        let rs = sweep(&engine, Some(dicts), &specs, &[Task::Arith], n, 300)?;
        for r in rs {
            rows.push(json::obj(vec![("model", json::s(size)), ("row", result_json(&r))]));
        }
    }
    write_report(opts, "table3", json::obj(vec![
        ("exhibit", json::s("table3")),
        ("rows", json::arr(rows)),
    ]))
}

// ---------------------------------------------------------------------------
// Fig 5 — 4-bit-weight model: Lexico vs quantization baselines
// ---------------------------------------------------------------------------

pub fn fig5(opts: &ReproOpts) -> Result<()> {
    println!("Fig 5: Lexico on a weight-quantized (int4) model (arith)\n");
    let n = samples(opts, 60);
    let mut w = Weights::load(opts.artifacts.join("model_L.bin"))?;
    w.fake_quantize_int4(16);
    let engine = Engine::new(w);
    let dicts = load_dicts(&opts.artifacts, "L", 1024)?;
    print_header();
    let mut specs = vec!["full".to_string()];
    for s in [2usize, 4, 6, 8] {
        specs.push(format!("lexico:s={s},nb={NB}"));
    }
    specs.push("kivi:bits=4,g=16,nb=16".into());
    specs.push("kivi:bits=2,g=16,nb=16".into());
    specs.push("pertoken:bits=4,g=16,nb=4".into());
    let rs = sweep(&engine, Some(dicts), &specs, &[Task::Arith], n, 500)?;
    write_report(opts, "fig5", json::obj(vec![
        ("exhibit", json::s("fig5")),
        ("note", json::s("L model, int4 fake-quantized weights (g=16)")),
        ("rows", json::arr(rs.iter().map(result_json).collect())),
    ]))
}

// ---------------------------------------------------------------------------
// Fig 6 — MMLU-Pro substitutes (arith-hard / sort) across methods
// ---------------------------------------------------------------------------

pub fn fig6(opts: &ReproOpts) -> Result<()> {
    // `sort` instances are shorter than the recency buffer, so every method
    // reports ~100% KV on them; `copy` is the long-range hard task that
    // actually exercises compression — used as the second panel here.
    println!("Fig 6: hard-task tradeoffs (arith-hard ≙ Engineering, copy ≙ Law)\n");
    let n = samples(opts, 60);
    let engine = load_engine(&opts.artifacts, "M")?;
    let dicts = load_dicts(&opts.artifacts, "M", 1024)?;
    print_header();
    let mut specs = vec!["full".to_string()];
    for s in [2usize, 4, 6, 8] {
        specs.push(format!("lexico:s={s},nb={NB}"));
    }
    specs.push("kivi:bits=2,g=16,nb=16".into());
    specs.push("kivi:bits=4,g=16,nb=16".into());
    specs.push("pertoken:bits=2,g=16,nb=4".into());
    specs.push("pertoken:bits=4,g=16,nb=4".into());
    specs.push("zipcache:hi=4,lo=2,g=16,frac=0.2,nb=16".into());
    specs.push("snapkv:cap=48,win=8".into());
    specs.push("pyramidkv:cap=48,win=8".into());
    let rs = sweep(&engine, Some(dicts), &specs, &[Task::ArithHard, Task::Copy], n, 600)?;
    write_report(opts, "fig6", json::obj(vec![
        ("exhibit", json::s("fig6")),
        ("rows", json::arr(rs.iter().map(result_json).collect())),
    ]))
}

// ---------------------------------------------------------------------------
// Table 4 — error-threshold (δ) ablation
// ---------------------------------------------------------------------------

pub fn table4(opts: &ReproOpts) -> Result<()> {
    println!("Table 4: error-threshold δ ablation (N=256, FP16 coefs, max s=8)\n");
    let n = samples(opts, 50);
    let engine = load_engine(&opts.artifacts, "M")?;
    let dicts = load_dicts(&opts.artifacts, "M", 256)?;
    print_header();
    let mut specs = vec!["full".to_string()];
    for delta in ["0.2", "0.3", "0.4", "0.5"] {
        specs.push(format!("lexico:s=8,delta={delta},nb={NB},fp16"));
    }
    let rs = sweep(&engine, Some(dicts), &specs, &LONG_SUITE, n, 700)?;
    write_report(opts, "table4", json::obj(vec![
        ("exhibit", json::s("table4")),
        ("rows", json::arr(rs.iter().map(result_json).collect())),
    ]))
}

// ---------------------------------------------------------------------------
// Table 5 — buffer ↔ sparse-representation balance at fixed 25% budget
// ---------------------------------------------------------------------------

pub fn table5(opts: &ReproOpts) -> Result<()> {
    println!("Table 5: (s, n_b) frontier at a fixed ~25% KV budget\n");
    let n = samples(opts, 50);
    let engine = load_engine(&opts.artifacts, "M")?;
    let dicts = load_dicts(&opts.artifacts, "M", 256)?;
    let m = engine.shape().head_dim;
    // typical context length of the long suite (measured):
    let t_ctx = 230.0f64;
    print_header();
    let mut rows = Vec::new();
    for s in [1usize, 2, 4, 6, 8] {
        // FP16 coefficients (paper's Table 5 setting): row = 4s+2 bytes
        let r = crate::sparse::memory::csr_ratio(s, m, crate::sparse::CoefMode::Fp16);
        // budget: [(T−nb)·r·2m·2 + nb·2m·2] / (T·2m·2) = 0.25
        let nb = if r < 0.25 {
            (t_ctx * (0.25 - r) / (1.0 - r)).round() as usize
        } else {
            0
        };
        let spec = format!("lexico:s={s},nb={nb},fp16");
        for task in [Task::Needle, Task::Lm, Task::Copy] {
            let res = evaluate(&engine, Some(dicts.clone()), &spec,
                               &EvalConfig::new(task, n, 800))?;
            println!("{}  (nb={nb})", crate::eval::format_row(&res));
            rows.push(json::obj(vec![
                ("s", json::num(s as f64)),
                ("nb", json::num(nb as f64)),
                ("row", result_json(&res)),
            ]));
        }
    }
    write_report(opts, "table5", json::obj(vec![
        ("exhibit", json::s("table5")),
        ("budget", json::num(0.25)),
        ("rows", json::arr(rows)),
    ]))
}

// ---------------------------------------------------------------------------
// Fig 7 / Tables 9–10 — performance without the buffer
// ---------------------------------------------------------------------------

pub fn fig7(opts: &ReproOpts) -> Result<()> {
    println!("Fig 7: Lexico with vs without the recency buffer (N=256, FP16)\n");
    let n = samples(opts, 50);
    let mut rows = Vec::new();
    for size in ["M", "L"] {
        let engine = load_engine(&opts.artifacts, size)?;
        // N=256 dictionaries ship for M; L falls back to its N=1024 set
        let dicts = load_dicts(&opts.artifacts, size, 256)
            .or_else(|_| load_dicts(&opts.artifacts, size, 1024))?;
        println!("--- model {size} ---");
        print_header();
        let mut specs = Vec::new();
        for s in [2usize, 4, 6, 8] {
            specs.push(format!("lexico:s={s},nb={NB},fp16"));
            specs.push(format!("lexico:s={s},nb=0,fp16"));
        }
        let rs = sweep(&engine, Some(dicts), &specs,
                       &[Task::Needle, Task::Copy, Task::Arith], n, 900)?;
        for r in rs {
            rows.push(json::obj(vec![("model", json::s(size)), ("row", result_json(&r))]));
        }
    }
    write_report(opts, "fig7", json::obj(vec![
        ("exhibit", json::s("fig7_tables9_10")),
        ("rows", json::arr(rows)),
    ]))
}

// ---------------------------------------------------------------------------
// Table 6 — adaptive dictionary learning
// ---------------------------------------------------------------------------

pub fn table6(opts: &ReproOpts) -> Result<()> {
    println!("Table 6: adaptive dictionaries on arith (base N=256 + ≤256 atoms)\n");
    let n = samples(opts, 80);
    let engine = load_engine(&opts.artifacts, "M")?;
    let dicts = load_dicts(&opts.artifacts, "M", 256)?;
    print_header();
    let mut specs = vec![
        "full".to_string(),
        format!("lexico:s=4,nb={NB},fp16"), // w/o adaptation
    ];
    for delta in ["0.25", "0.30", "0.35"] {
        specs.push(format!("lexico:s=4,nb={NB},fp16,adaptive=256:{delta}"));
    }
    let rs = sweep(&engine, Some(dicts), &specs, &[Task::Arith], n, 1000)?;
    write_report(opts, "table6", json::obj(vec![
        ("exhibit", json::s("table6")),
        ("rows", json::arr(rs.iter().map(result_json).collect())),
    ]))
}

// ---------------------------------------------------------------------------
// Table 7 — latency decomposition (also: benches/table7_latency.rs)
// ---------------------------------------------------------------------------

pub fn table7(opts: &ReproOpts) -> Result<()> {
    println!("Table 7: per-token latency decomposition (context ≈ 500 tokens)\n");
    let engine = load_engine(&opts.artifacts, "M")?;
    let shape = engine.shape();
    let t_ctx = 500usize.min(engine.weights.cfg.max_seq - 40);
    let mut rng = Rng::new(3);
    let prompt: Vec<u32> = {
        let mut v = vec![tasks::BOS];
        v.extend(tasks::encode(&tasks::gen_lm_text(&mut rng, t_ctx - 2)));
        v.truncate(t_ctx);
        v
    };
    let reps = if opts.fast { 20 } else { 100 };
    let mut rows = Vec::new();
    // standard forward (full cache)
    let mut full = FullCache::new(shape);
    let _ = engine.prefill(&prompt, &mut full);
    let mut pos = prompt.len();
    let t0 = Instant::now();
    for i in 0..reps {
        let _ = engine.decode_step((5 + i % 30) as u32, pos, &mut full);
        pos += 1;
    }
    let std_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
    println!("standard forward (qKᵀ)                 : {std_ms:>8.3} ms/token");
    rows.push(json::obj(vec![("what", json::s("standard_forward")), ("ms", json::num(std_ms))]));

    for n_atoms in [256usize, 1024] {
        let dicts = load_dicts(&opts.artifacts, "M", n_atoms)?;
        // lexico forward: attend over compressed cache (na=0 during timing
        // by using a huge buffer margin → no OMP inside the loop)
        let cfg = LexicoConfig { sparsity: 6, n_buffer: NB, n_approx: 0, ..Default::default() };
        let mut lex = LexicoCache::new(shape, dicts.clone(), cfg);
        let _ = engine.prefill(&prompt, &mut lex);
        let mut pos = prompt.len();
        let t0 = Instant::now();
        for i in 0..reps {
            let _ = engine.decode_step((5 + i % 30) as u32, pos, &mut lex);
            pos += 1;
        }
        let fwd_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        // OMP: compress one token's K+V per layer/kv-head (s=6)
        let mut ws = crate::omp::OmpWorkspace::new(n_atoms, shape.head_dim, 6);
        let xs: Vec<Vec<f32>> = (0..shape.n_layers * shape.n_kv_heads * 2)
            .map(|_| rng.normal_vec(shape.head_dim))
            .collect();
        let t0 = Instant::now();
        for _ in 0..reps {
            for (i, x) in xs.iter().enumerate() {
                let layer = i / (shape.n_kv_heads * 2);
                let d = if i % 2 == 0 { &dicts.keys[layer] } else { &dicts.values[layer] };
                let _ = crate::omp::omp_encode(&d.atoms, d.n, d.m, x, 6, 0.0, &mut ws);
            }
        }
        let omp_ms = t0.elapsed().as_secs_f64() * 1e3 / reps as f64;
        println!("Lexico forward  q(K_csr·D_kᵀ)ᵀ N={n_atoms:<5}: {fwd_ms:>8.3} ms/token");
        println!("Lexico OMP (per generated token) N={n_atoms:<4}: {omp_ms:>8.3} ms/token");
        rows.push(json::obj(vec![
            ("what", json::s(&format!("lexico_forward_N{n_atoms}"))),
            ("ms", json::num(fwd_ms)),
        ]));
        rows.push(json::obj(vec![
            ("what", json::s(&format!("omp_N{n_atoms}"))),
            ("ms", json::num(omp_ms)),
        ]));
    }
    write_report(opts, "table7", json::obj(vec![
        ("exhibit", json::s("table7")),
        ("context", json::num(t_ctx as f64)),
        ("rows", json::arr(rows)),
    ]))
}
