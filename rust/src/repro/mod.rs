//! Repro drivers — one per table/figure of the paper (DESIGN.md §4 index).
//!
//! Each driver prints rows in the paper's format and writes a JSON record
//! under `reports/`. Drivers are registered in [`run`]; `--fast` shrinks
//! sample counts for smoke runs.

pub mod exhibits;

use anyhow::{bail, Result};

/// Shared driver options.
#[derive(Clone, Debug)]
pub struct ReproOpts {
    pub fast: bool,
    pub artifacts: std::path::PathBuf,
    pub reports: std::path::PathBuf,
}

impl Default for ReproOpts {
    fn default() -> Self {
        ReproOpts {
            fast: false,
            artifacts: crate::artifacts_dir(),
            reports: crate::reports_dir(),
        }
    }
}

pub const EXHIBITS: &[&str] = &[
    "fig1", "fig3", "fig5", "fig6", "fig7",
    "table1", "table2", "table3", "table4", "table5", "table6", "table7",
];

/// Dispatch a driver by exhibit name.
pub fn run(exhibit: &str, opts: &ReproOpts) -> Result<()> {
    std::fs::create_dir_all(&opts.reports).ok();
    match exhibit {
        "fig1" => exhibits::fig1(opts),
        "fig3" => exhibits::fig3(opts),
        "fig5" => exhibits::fig5(opts),
        "fig6" => exhibits::fig6(opts),
        "fig7" => exhibits::fig7(opts),
        "table1" => exhibits::table1(opts),
        "table2" => exhibits::table2(opts),
        "table3" => exhibits::table3(opts),
        "table4" => exhibits::table4(opts),
        "table5" => exhibits::table5(opts),
        "table6" => exhibits::table6(opts),
        "table7" => exhibits::table7(opts),
        "all" => {
            for e in EXHIBITS {
                println!("\n================= {e} =================");
                run(e, opts)?;
            }
            Ok(())
        }
        other => bail!("unknown exhibit '{other}'; known: {EXHIBITS:?} or 'all'"),
    }
}
