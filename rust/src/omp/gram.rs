//! Precomputed-Gram Batch-OMP — the encode-path twin of the PR 6 shared-
//! dictionary decode GEMM (DESIGN.md §12).
//!
//! The canonical pursuit pays O(N·m) per iteration per vector to re-stream
//! the dictionary for correlations. But the universal dictionary is fixed
//! and input-agnostic, so its Gram matrix G = D·Dᵀ can be computed once per
//! process ([`crate::tensor::par_syrk`], cached on the
//! [`Dictionary`](crate::dict::Dictionary)) and every pursuit rewritten in
//! coefficient space (Rubinstein, Zibulevsky & Elad 2008):
//!
//! - initial projections α⁰ = X·Dᵀ for the **whole batch** are one GEMM —
//!   the only pass over the dictionary this tier ever makes;
//! - each iteration updates the working correlations as α ← α⁰ − G_S·β,
//!   O(N·s) per vector instead of O(N·m);
//! - the Cholesky's new Gram column is a row read of G instead of s dots;
//! - the residual norm follows the scalar recurrence ‖r‖² = ‖x‖² − βᵀα⁰_S
//!   (exact because β solves G_S·β = α⁰_S, which kills the quadratic
//!   term), so **no residual vectors exist at all**.
//!
//! **Determinism contract** (the fast-math precedent, DESIGN.md §10): the
//! tier is bitwise self-identical at every thread count — every mutable
//! stripe is per-vector, every shared FP op (the α⁰ GEMM, the axpy
//! refresh) runs the canonical kernels in a fixed order. Against the
//! canonical encoder it is tolerance-pinned, not bitwise: correlations are
//! updated by recurrence rather than recomputed from the residual, so
//! low-order bits differ and argmax near-ties may resolve differently. On
//! identical selection orders the coefficients *are* bitwise equal,
//! because `par_syrk` built every G entry with the same canonical `dot`
//! the canonical tier would have issued (and `dot` is bitwise
//! commutative: same multiplies, same fixed reduction tree). Opt-in via
//! `--gram-omp` / `LEXICO_GRAM_OMP=1`; canonical stays the default.

use super::batch::BatchOmpWorkspace;
use super::SparseCode;
use crate::exec::SendPtr;
use crate::tensor::{axpy, dot, par_matmul_bt};

/// Sparse-code `batch` vectors (`xs` is `[batch, m]` row-major) over
/// `atoms` `[N, m]` using the precomputed Gram matrix `gram` (`[N, N]`,
/// full symmetric storage as produced by [`crate::tensor::par_syrk`]).
/// Termination semantics match [`omp_encode`](super::omp_encode): at most
/// `s_max` atoms, optional `delta` early termination — evaluated on the
/// recurrence-tracked residual norm.
#[allow(clippy::too_many_arguments)]
pub fn omp_encode_batch_gram(
    atoms: &[f32],
    n_atoms: usize,
    m: usize,
    gram: &[f32],
    xs: &[f32],
    batch: usize,
    s_max: usize,
    delta: f32,
    ws: &mut BatchOmpWorkspace,
) -> Vec<SparseCode> {
    debug_assert_eq!(atoms.len(), n_atoms * m);
    debug_assert_eq!(gram.len(), n_atoms * n_atoms);
    debug_assert_eq!(xs.len(), batch * m);
    let s_cap = s_max.min(n_atoms).min(m.max(1) * 4); // same defensive cap
    ws.ensure(batch, n_atoms, m, s_cap);
    ws.ensure_gram(batch, n_atoms);

    // THE amortized step: initial projections for the whole batch in one
    // GEMM (each α⁰ entry is one whole canonical dot — bitwise equal to
    // the canonical tier's iteration-0 correlations, at any thread count).
    {
        let pool = ws.pool.clone();
        par_matmul_bt(
            &pool,
            &mut ws.alpha0[..batch * n_atoms],
            xs,
            atoms,
            batch,
            m,
            n_atoms,
        );
    }

    for bi in 0..batch {
        let x = &xs[bi * m..(bi + 1) * m];
        ws.sel[bi].clear();
        ws.mask[bi * n_atoms..(bi + 1) * n_atoms].fill(false);
        ws.done[bi] = false;
        let n2 = dot(x, x);
        ws.xnorm2[bi] = n2;
        ws.err2[bi] = n2;
        ws.stop[bi] = (delta * n2.sqrt()).max(1e-12);
        // working correlations start at α⁰
        ws.corr[bi * n_atoms..(bi + 1) * n_atoms]
            .copy_from_slice(&ws.alpha0[bi * n_atoms..(bi + 1) * n_atoms]);
    }

    for _iter in 0..s_cap {
        // which vectors still have budget and a residual above threshold?
        // (‖r‖ comes from the scalar recurrence — clamp guards the tiny
        // negative dust FP cancellation can leave once r ≈ 0)
        ws.active.clear();
        for bi in 0..batch {
            if ws.done[bi] {
                continue;
            }
            if ws.err2[bi].max(0.0).sqrt() <= ws.stop[bi] {
                ws.done[bi] = true;
            } else {
                ws.active.push(bi);
            }
        }
        let a_cnt = ws.active.len();
        if a_cnt == 0 {
            break;
        }

        // Per-vector: argmax over working correlations, Cholesky via Gram
        // row reads, triangular solves, then the two recurrences. One
        // shard per active vector; every mutable view below is that
        // vector's private stripe, so shards are disjoint and the result
        // is bitwise independent of the thread count.
        {
            let pool = ws.pool.clone();
            let active: &[usize] = &ws.active;
            let alpha0: &[f32] = &ws.alpha0;
            let xnorm2: &[f32] = &ws.xnorm2;
            let corr_ptr = SendPtr::new(ws.corr.as_mut_ptr());
            let mask_ptr = SendPtr::new(ws.mask.as_mut_ptr());
            let sel_ptr = SendPtr::new(ws.sel.as_mut_ptr());
            let done_ptr = SendPtr::new(ws.done.as_mut_ptr());
            let chol_ptr = SendPtr::new(ws.chol.as_mut_ptr());
            let alpha_ptr = SendPtr::new(ws.alpha.as_mut_ptr());
            let y_ptr = SendPtr::new(ws.y.as_mut_ptr());
            let z_ptr = SendPtr::new(ws.z.as_mut_ptr());
            let b_ptr = SendPtr::new(ws.b.as_mut_ptr());
            let err2_ptr = SendPtr::new(ws.err2.as_mut_ptr());
            pool.parallel_for(a_cnt, move |ai| {
                let bi = active[ai];
                // SAFETY: each shard owns exactly one `bi`; every view
                // below is that vector's private stripe.
                let sel = unsafe { &mut *sel_ptr.get().add(bi) };
                let mask = unsafe {
                    std::slice::from_raw_parts_mut(mask_ptr.get().add(bi * n_atoms), n_atoms)
                };
                let done = unsafe { &mut *done_ptr.get().add(bi) };
                let corr = unsafe {
                    std::slice::from_raw_parts_mut(corr_ptr.get().add(bi * n_atoms), n_atoms)
                };
                let chol = unsafe {
                    std::slice::from_raw_parts_mut(
                        chol_ptr.get().add(bi * s_cap * s_cap),
                        s_cap * s_cap,
                    )
                };
                let alpha =
                    unsafe { std::slice::from_raw_parts_mut(alpha_ptr.get().add(bi * s_cap), s_cap) };
                let yv = unsafe { std::slice::from_raw_parts_mut(y_ptr.get().add(bi * s_cap), s_cap) };
                let z = unsafe { std::slice::from_raw_parts_mut(z_ptr.get().add(bi * s_cap), s_cap) };
                let bcol = unsafe { std::slice::from_raw_parts_mut(b_ptr.get().add(bi * s_cap), s_cap) };
                let err2 = unsafe { &mut *err2_ptr.get().add(bi) };
                let a0 = &alpha0[bi * n_atoms..(bi + 1) * n_atoms];

                let i = sel.len();
                let mut best = usize::MAX;
                let mut best_abs = -1.0f32;
                for n in 0..n_atoms {
                    let a = corr[n].abs();
                    // same scan shape as the canonical tiers: improvement
                    // test first, then the O(1) selected-atom bitmask
                    if a > best_abs && !mask[n] {
                        best_abs = a;
                        best = n;
                    }
                }
                if best == usize::MAX {
                    *done = true; // dictionary exhausted
                    return;
                }

                // Cholesky update: the new Gram column is a row read of G —
                // the very dots the canonical tier computes on the fly,
                // precomputed once per process.
                let g_best = &gram[best * n_atoms..(best + 1) * n_atoms];
                for (k, &p) in sel.iter().enumerate() {
                    bcol[k] = g_best[p];
                }
                for k in 0..i {
                    let mut w = bcol[k];
                    for l in 0..k {
                        w -= chol[k * s_cap + l] * chol[i * s_cap + l];
                    }
                    chol[i * s_cap + k] = w / chol[k * s_cap + k];
                }
                let mut diag = 1.0f32;
                for l in 0..i {
                    diag -= chol[i * s_cap + l] * chol[i * s_cap + l];
                }
                if diag <= 1e-10 {
                    *done = true; // atom numerically in span of selection
                    return;
                }
                chol[i * s_cap + i] = diag.sqrt();
                sel.push(best);
                mask[best] = true;
                alpha[i] = a0[best]; // = ⟨x, atom⟩, already computed

                // Solve L z = α⁰_S, then Lᵀ y = z (identical to canonical).
                let k_sel = i + 1;
                for k in 0..k_sel {
                    let mut zv = alpha[k];
                    for l in 0..k {
                        zv -= chol[k * s_cap + l] * z[l];
                    }
                    z[k] = zv / chol[k * s_cap + k];
                }
                for k in (0..k_sel).rev() {
                    let mut val = z[k];
                    for l in k + 1..k_sel {
                        val -= chol[l * s_cap + k] * yv[l];
                    }
                    yv[k] = val / chol[k * s_cap + k];
                }

                // correlation refresh in coefficient space:
                // α ← α⁰ − Σ_k y_k · G[sel_k] — O(N·|S|), replacing the
                // canonical tier's O(N·m) dictionary pass.
                corr.copy_from_slice(a0);
                for (k, &p) in sel.iter().enumerate() {
                    axpy(corr, -yv[k], &gram[p * n_atoms..(p + 1) * n_atoms]);
                }
                // residual-norm recurrence: ‖r‖² = ‖x‖² − βᵀα⁰_S, exact
                // because β solves G_S·β = α⁰_S — no residual vector.
                let mut e = xnorm2[bi];
                for k in 0..k_sel {
                    e -= yv[k] * alpha[k];
                }
                *err2 = e;
            });
        }
    }

    let codes = (0..batch)
        .map(|bi| {
            let k = ws.sel[bi].len();
            SparseCode {
                idx: ws.sel[bi].iter().map(|&p| p as u16).collect(),
                val: ws.y[bi * s_cap..bi * s_cap + k].to_vec(),
            }
        })
        .collect();
    ws.shrink(batch, n_atoms, m, s_cap);
    codes
}

/// Convenience wrapper allocating its own workspace (tests / cold paths).
#[allow(clippy::too_many_arguments)]
pub fn omp_encode_batch_gram_alloc(
    atoms: &[f32],
    n_atoms: usize,
    m: usize,
    gram: &[f32],
    xs: &[f32],
    batch: usize,
    s_max: usize,
    delta: f32,
) -> Vec<SparseCode> {
    let mut ws = BatchOmpWorkspace::new();
    omp_encode_batch_gram(atoms, n_atoms, m, gram, xs, batch, s_max, delta, &mut ws)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ExecPool;
    use crate::omp::{omp_encode_alloc, rel_error};
    use crate::tensor::{norm2, syrk};
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    fn random_unit_atoms(rng: &mut Rng, n: usize, m: usize) -> Vec<f32> {
        let mut atoms = rng.normal_vec(n * m);
        for a in atoms.chunks_mut(m) {
            let nrm = norm2(a).max(1e-12);
            a.iter_mut().for_each(|x| *x /= nrm);
        }
        atoms
    }

    fn gram_of(atoms: &[f32], n: usize, m: usize) -> Vec<f32> {
        let mut g = vec![0.0; n * n];
        syrk(&mut g, atoms, n, m);
        g
    }

    #[test]
    fn gram_tier_is_bitwise_self_identical_at_every_thread_count() {
        // (a) of the parity suite: the tier's own determinism contract —
        // identical codes through 1-, 2- and 4-thread pools, and across
        // repeated calls on a reused workspace.
        let mut rng = Rng::new(71);
        let (m, n, s, batch) = (16usize, 128usize, 6usize, 17usize);
        let atoms = random_unit_atoms(&mut rng, n, m);
        let g = gram_of(&atoms, n, m);
        let xs = rng.normal_vec(batch * m);
        let runs: Vec<Vec<SparseCode>> = [1usize, 2, 4]
            .iter()
            .map(|&t| {
                let mut ws = BatchOmpWorkspace::with_pool(Arc::new(ExecPool::new(t)));
                omp_encode_batch_gram(&atoms, n, m, &g, &xs, batch, s, 0.0, &mut ws)
            })
            .collect();
        for bi in 0..batch {
            for (ri, run) in runs.iter().enumerate().skip(1) {
                assert_eq!(runs[0][bi].idx, run[bi].idx, "T-run {ri} vec {bi}: indices diverged");
                assert_eq!(runs[0][bi].val, run[bi].val, "T-run {ri} vec {bi}: values diverged");
            }
        }
        let mut ws = BatchOmpWorkspace::with_pool(Arc::new(ExecPool::new(2)));
        let first = omp_encode_batch_gram(&atoms, n, m, &g, &xs, batch, s, 0.0, &mut ws);
        let second = omp_encode_batch_gram(&atoms, n, m, &g, &xs, batch, s, 0.0, &mut ws);
        for bi in 0..batch {
            assert_eq!(first[bi].idx, second[bi].idx, "workspace reuse changed vec {bi}");
            assert_eq!(first[bi].val, second[bi].val, "workspace reuse changed vec {bi}");
        }
    }

    #[test]
    fn gram_tier_recovers_exact_supports_like_canonical() {
        // (b): on k-sparse signals over well-separated dictionaries the
        // gram tier finds the same support as canonical OMP; when the
        // selection *order* also matches, the coefficients are bitwise
        // equal (the Cholesky reads from G the same dots the canonical
        // tier computes on the fly).
        Prop::new(48).check("gram_support_recovery", |rng, size| {
            let m = 16 + (size % 3) * 8;
            let n = 4 * m;
            let atoms = random_unit_atoms(rng, n, m);
            let g = gram_of(&atoms, n, m);
            let k = 1 + rng.below(3);
            let mut x = vec![0.0; m];
            for _ in 0..k {
                let id = rng.below(n);
                let c = rng.range_f32(0.5, 2.0) * if rng.below(2) == 0 { 1.0 } else { -1.0 };
                crate::tensor::axpy(&mut x, c, &atoms[id * m..(id + 1) * m]);
            }
            let canon = omp_encode_alloc(&atoms, n, m, &x, k, 0.0);
            let gcodes = omp_encode_batch_gram_alloc(&atoms, n, m, &g, &x, 1, k, 0.0);
            let mut sc = canon.idx.clone();
            let mut sg = gcodes[0].idx.clone();
            sc.sort_unstable();
            sg.sort_unstable();
            if sg != sc {
                return Err(format!("supports diverged: {sg:?} vs {sc:?}"));
            }
            if gcodes[0].idx == canon.idx && gcodes[0].val != canon.val {
                return Err("identical selection order but coefficients diverged".into());
            }
            let err = rel_error(&atoms, m, &x, &gcodes[0]);
            if err < 1e-3 {
                Ok(())
            } else {
                Err(format!("k={k} err={err}"))
            }
        });
    }

    #[test]
    fn gram_tier_rel_error_within_tolerance_of_canonical() {
        // (c): on arbitrary signals the tiers may resolve argmax near-ties
        // differently, but the gram tier's reconstruction can be no worse
        // than canonical beyond a 1e-4 slack — across random shapes,
        // batches and both termination modes.
        for &delta in &[0.0f32, 0.4] {
            Prop::new(24).seed(0x67A1 + delta.to_bits() as u64).check(
                "gram_rel_error",
                |rng, size| {
                    let m = 8 + (size % 4) * 8;
                    let n = 4 * m;
                    let s = 1 + rng.below(8);
                    let batch = 1 + rng.below(5);
                    let atoms = random_unit_atoms(rng, n, m);
                    let g = gram_of(&atoms, n, m);
                    let xs = rng.normal_vec(batch * m);
                    let gcodes =
                        omp_encode_batch_gram_alloc(&atoms, n, m, &g, &xs, batch, s, delta);
                    for bi in 0..batch {
                        let x = &xs[bi * m..(bi + 1) * m];
                        let canon = omp_encode_alloc(&atoms, n, m, x, s, delta);
                        let ec = rel_error(&atoms, m, x, &canon);
                        let eg = rel_error(&atoms, m, x, &gcodes[bi]);
                        if eg > ec + 1e-4 {
                            return Err(format!(
                                "vec {bi} (m={m} n={n} s={s} δ={delta}): gram {eg} > canon {ec} + 1e-4"
                            ));
                        }
                    }
                    Ok(())
                },
            );
        }
    }

    #[test]
    fn canonical_and_gram_calls_share_one_workspace() {
        // Tier interleaving on one workspace (the cache owns exactly one
        // `BatchOmpWorkspace`): neither tier may corrupt the other's calls.
        let mut ws = BatchOmpWorkspace::new();
        let mut rng = Rng::new(29);
        let (m, n, s, batch) = (16usize, 64usize, 4usize, 7usize);
        let atoms = random_unit_atoms(&mut rng, n, m);
        let g = gram_of(&atoms, n, m);
        for round in 0..3 {
            let xs = rng.normal_vec(batch * m);
            let gshared = omp_encode_batch_gram(&atoms, n, m, &g, &xs, batch, s, 0.0, &mut ws);
            let gfresh = omp_encode_batch_gram_alloc(&atoms, n, m, &g, &xs, batch, s, 0.0);
            let cshared =
                crate::omp::omp_encode_batch(&atoms, n, m, &xs, batch, s, 0.0, &mut ws);
            for bi in 0..batch {
                assert_eq!(gshared[bi].idx, gfresh[bi].idx, "round {round} vec {bi}");
                assert_eq!(gshared[bi].val, gfresh[bi].val, "round {round} vec {bi}");
                let solo = omp_encode_alloc(&atoms, n, m, &xs[bi * m..(bi + 1) * m], s, 0.0);
                assert_eq!(cshared[bi].idx, solo.idx, "round {round} vec {bi} (canonical)");
                assert_eq!(cshared[bi].val, solo.val, "round {round} vec {bi} (canonical)");
            }
        }
    }

    #[test]
    fn zero_vector_and_empty_batch() {
        let mut rng = Rng::new(5);
        let (m, n) = (16usize, 64usize);
        let atoms = random_unit_atoms(&mut rng, n, m);
        let g = gram_of(&atoms, n, m);
        let xs = vec![0.0f32; m];
        let codes = omp_encode_batch_gram_alloc(&atoms, n, m, &g, &xs, 1, 4, 0.0);
        assert_eq!(codes[0].nnz(), 0, "zero vector must terminate before iteration 1");
        let none = omp_encode_batch_gram_alloc(&atoms, n, m, &g, &[], 0, 4, 0.0);
        assert!(none.is_empty());
    }

    #[test]
    fn delta_termination_tracks_the_recurrence() {
        // The recurrence-tracked norm must actually stop the pursuit: with
        // a generous delta the gram tier stops early, and the achieved
        // error respects the bound (or the budget ran out).
        let mut rng = Rng::new(13);
        let (m, n, s) = (32usize, 128usize, 12usize);
        let atoms = random_unit_atoms(&mut rng, n, m);
        let g = gram_of(&atoms, n, m);
        let x = rng.normal_vec(m);
        let code = &omp_encode_batch_gram_alloc(&atoms, n, m, &g, &x, 1, s, 0.5)[0];
        let err = rel_error(&atoms, m, &x, code);
        assert!(
            code.nnz() == s || err <= 0.5 + 1e-3,
            "stopped at nnz={} with err={err}",
            code.nnz()
        );
        let full = &omp_encode_batch_gram_alloc(&atoms, n, m, &g, &x, 1, s, 0.0)[0];
        assert!(full.nnz() >= code.nnz(), "delta run selected more atoms than full run");
    }
}
