//! GEMM-batched Orthogonal Matching Pursuit.
//!
//! [`omp_encode`](super::omp_encode) spends almost all of its time in the
//! correlation step — an O(N·m) streaming pass over the dictionary per
//! iteration per vector. When many vectors are compressed at once (a layer
//! overflow compresses `n_a × n_kv_heads` pending tokens; prefill compresses
//! hundreds), running that step per vector re-streams the same N·m array
//! once per vector per iteration.
//!
//! [`omp_encode_batch`] instead runs the correlation step for *all still
//! active* vectors as one `matmul_bt` GEMM (`R[A,m] · Dᵀ[m,N]`), so each
//! dictionary atom is loaded once per iteration and serves every pending
//! residual — the same amortization the paper uses to justify batched
//! sparse coding (§3.4) and that CSR applies to whole-cache encoding. Both
//! stages run on the workspace's [`ExecPool`]: the correlation GEMM is
//! sharded by atom blocks, and the per-vector argmax + Cholesky update +
//! triangular solves + residual refresh fan out one shard per active
//! vector (each vector's state is private, so shards are disjoint and the
//! result is bitwise independent of the thread count).
//!
//! **Parity contract:** for every input vector the batch encoder performs
//! the exact same floating-point operations in the exact same order as the
//! sequential encoder (the GEMM computes `dot(r, atom)` with the identical
//! accumulation pattern as the sequential `dot(atom, r)`), so
//! `omp_encode_batch(xs)[i] == omp_encode(xs[i])` bit for bit. A property
//! test below enforces this.

use std::sync::Arc;

use super::SparseCode;
use crate::exec::{self, ExecPool, SendPtr};
use crate::tensor::{axpy, dot, norm2, par_matmul_bt};

/// Reusable buffers for [`omp_encode_batch`]; grows monotonically, so one
/// workspace serves any mix of (batch, N, m, s) shapes without reallocating
/// in steady state. Carries the [`ExecPool`] the encoder runs on (the
/// process default unless [`BatchOmpWorkspace::with_pool`] /
/// [`BatchOmpWorkspace::set_pool`] say otherwise) — results are bitwise
/// independent of the pool's thread count.
pub struct BatchOmpWorkspace {
    /// worker pool for the correlation GEMM + the per-vector solves
    pub(crate) pool: Arc<ExecPool>,
    /// compacted residuals of the still-active vectors, `[A, m]`
    pub(crate) rs: Vec<f32>,
    /// correlations of the active vectors, `[A, N]` (the gram tier reuses
    /// this as the per-vector working correlations, `[B, N]`)
    pub(crate) corr: Vec<f32>,
    /// per-vector residuals, `[B, m]`
    pub(crate) r: Vec<f32>,
    /// per-vector lower-triangular Cholesky factors, `[B, s*s]`
    pub(crate) chol: Vec<f32>,
    /// per-vector `D_Sᵀ x`, `[B, s]`
    pub(crate) alpha: Vec<f32>,
    /// per-vector coefficients, `[B, s]`
    pub(crate) y: Vec<f32>,
    /// per-vector forward-solve scratch, `[B, s]` (fully rewritten per solve)
    pub(crate) z: Vec<f32>,
    /// per-vector new-Gram-column scratch, `[B, s]`
    pub(crate) b: Vec<f32>,
    /// per-vector selected atom ids
    pub(crate) sel: Vec<Vec<usize>>,
    /// per-vector selected-atom bitmask, `[B, N]` (O(1) argmax mask scan)
    pub(crate) mask: Vec<bool>,
    /// indices of vectors still running this iteration
    pub(crate) active: Vec<usize>,
    /// per-vector early-termination threshold `δ·‖x‖`
    pub(crate) stop: Vec<f32>,
    /// per-vector finished flag
    pub(crate) done: Vec<bool>,
    /// gram tier: initial projections α⁰ = X·Dᵀ, `[B, N]`
    pub(crate) alpha0: Vec<f32>,
    /// gram tier: per-vector ‖x‖² (seed of the residual-norm recurrence)
    pub(crate) xnorm2: Vec<f32>,
    /// gram tier: per-vector current ‖r‖² via the scalar recurrence
    pub(crate) err2: Vec<f32>,
}

/// Scratch-release policy shared with the attend path (DESIGN.md §10): a
/// buffer whose capacity exceeds this factor times the current call's need
/// is truncated and shrunk, so a one-off giant batch cannot pin its
/// high-water mark for the life of the workspace.
const SCRATCH_SHRINK_FACTOR: usize = 4;

fn shrink_scratch<T>(v: &mut Vec<T>, keep: usize) {
    if v.capacity() > keep.saturating_mul(SCRATCH_SHRINK_FACTOR) {
        v.truncate(keep);
        v.shrink_to(keep);
    }
}

impl Default for BatchOmpWorkspace {
    fn default() -> Self {
        Self::with_pool(exec::default_pool())
    }
}

impl BatchOmpWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// A workspace whose encodes run on `pool` (e.g. the batcher's pool).
    pub fn with_pool(pool: Arc<ExecPool>) -> Self {
        BatchOmpWorkspace {
            pool,
            rs: Vec::new(),
            corr: Vec::new(),
            r: Vec::new(),
            chol: Vec::new(),
            alpha: Vec::new(),
            y: Vec::new(),
            z: Vec::new(),
            b: Vec::new(),
            sel: Vec::new(),
            mask: Vec::new(),
            active: Vec::new(),
            stop: Vec::new(),
            done: Vec::new(),
            alpha0: Vec::new(),
            xnorm2: Vec::new(),
            err2: Vec::new(),
        }
    }

    pub fn pool(&self) -> &Arc<ExecPool> {
        &self.pool
    }

    pub fn set_pool(&mut self, pool: Arc<ExecPool>) {
        self.pool = pool;
    }

    pub(crate) fn ensure(&mut self, batch: usize, n_atoms: usize, m: usize, s_cap: usize) {
        if self.rs.len() < batch * m {
            self.rs.resize(batch * m, 0.0);
        }
        if self.corr.len() < batch * n_atoms {
            self.corr.resize(batch * n_atoms, 0.0);
        }
        if self.mask.len() < batch * n_atoms {
            self.mask.resize(batch * n_atoms, false);
        }
        if self.r.len() < batch * m {
            self.r.resize(batch * m, 0.0);
        }
        if self.chol.len() < batch * s_cap * s_cap {
            self.chol.resize(batch * s_cap * s_cap, 0.0);
        }
        if self.alpha.len() < batch * s_cap {
            self.alpha.resize(batch * s_cap, 0.0);
        }
        if self.y.len() < batch * s_cap {
            self.y.resize(batch * s_cap, 0.0);
        }
        if self.z.len() < batch * s_cap {
            self.z.resize(batch * s_cap, 0.0);
        }
        if self.b.len() < batch * s_cap {
            self.b.resize(batch * s_cap, 0.0);
        }
        if self.sel.len() < batch {
            self.sel.resize_with(batch, Vec::new);
        }
        if self.done.len() < batch {
            self.done.resize(batch, false);
        }
        if self.stop.len() < batch {
            self.stop.resize(batch, 0.0);
        }
    }

    /// Gram-tier extras on top of [`BatchOmpWorkspace::ensure`].
    pub(crate) fn ensure_gram(&mut self, batch: usize, n_atoms: usize) {
        if self.alpha0.len() < batch * n_atoms {
            self.alpha0.resize(batch * n_atoms, 0.0);
        }
        if self.xnorm2.len() < batch {
            self.xnorm2.resize(batch, 0.0);
        }
        if self.err2.len() < batch {
            self.err2.resize(batch, 0.0);
        }
    }

    /// Release over-grown scratch after a call (the PR 6 attend-scratch
    /// policy): buffers grow monotonically while encoding, but any buffer
    /// whose capacity exceeds 4× this call's need is truncated + shrunk.
    /// `sel`'s outer Vec is shrunk the same way (dropping a slot drops its
    /// inner Vec); inner `sel` vectors are bounded by `s_cap` and stay.
    pub(crate) fn shrink(&mut self, batch: usize, n_atoms: usize, m: usize, s_cap: usize) {
        shrink_scratch(&mut self.rs, batch * m);
        shrink_scratch(&mut self.corr, batch * n_atoms);
        shrink_scratch(&mut self.mask, batch * n_atoms);
        shrink_scratch(&mut self.r, batch * m);
        shrink_scratch(&mut self.chol, batch * s_cap * s_cap);
        shrink_scratch(&mut self.alpha, batch * s_cap);
        shrink_scratch(&mut self.y, batch * s_cap);
        shrink_scratch(&mut self.z, batch * s_cap);
        shrink_scratch(&mut self.b, batch * s_cap);
        shrink_scratch(&mut self.sel, batch);
        shrink_scratch(&mut self.active, batch);
        shrink_scratch(&mut self.stop, batch);
        shrink_scratch(&mut self.done, batch);
        shrink_scratch(&mut self.alpha0, batch * n_atoms);
        shrink_scratch(&mut self.xnorm2, batch);
        shrink_scratch(&mut self.err2, batch);
    }
}

/// Sparse-code `batch` vectors (`xs` is `[batch, m]` row-major) over `atoms`
/// `[N, m]` in one batched pursuit. Semantics per vector are identical to
/// [`omp_encode`](super::omp_encode): at most `s_max` atoms, optional
/// `delta` early termination, selected atoms masked out of the argmax scan.
#[allow(clippy::too_many_arguments)]
pub fn omp_encode_batch(
    atoms: &[f32],
    n_atoms: usize,
    m: usize,
    xs: &[f32],
    batch: usize,
    s_max: usize,
    delta: f32,
    ws: &mut BatchOmpWorkspace,
) -> Vec<SparseCode> {
    debug_assert_eq!(atoms.len(), n_atoms * m);
    debug_assert_eq!(xs.len(), batch * m);
    let s_cap = s_max.min(n_atoms).min(m.max(1) * 4); // same defensive cap
    ws.ensure(batch, n_atoms, m, s_cap);
    for bi in 0..batch {
        ws.r[bi * m..(bi + 1) * m].copy_from_slice(&xs[bi * m..(bi + 1) * m]);
        ws.sel[bi].clear();
        ws.mask[bi * n_atoms..(bi + 1) * n_atoms].fill(false);
        ws.done[bi] = false;
        ws.stop[bi] = (delta * norm2(&xs[bi * m..(bi + 1) * m])).max(1e-12);
    }

    for _iter in 0..s_cap {
        // which vectors still have budget and a residual above threshold?
        ws.active.clear();
        for bi in 0..batch {
            if ws.done[bi] {
                continue;
            }
            if norm2(&ws.r[bi * m..(bi + 1) * m]) <= ws.stop[bi] {
                ws.done[bi] = true;
            } else {
                ws.active.push(bi);
            }
        }
        let a_cnt = ws.active.len();
        if a_cnt == 0 {
            break;
        }

        // THE batched step: compact the active residuals and compute every
        // correlation in one GEMM — one streaming pass over the dictionary
        // serves all pending vectors, and the pool shards the pass by atom
        // blocks (each correlation is one whole dot, so results are bitwise
        // independent of the thread count).
        for ai in 0..a_cnt {
            let bi = ws.active[ai];
            ws.rs[ai * m..(ai + 1) * m].copy_from_slice(&ws.r[bi * m..(bi + 1) * m]);
        }
        par_matmul_bt(
            &ws.pool,
            &mut ws.corr[..a_cnt * n_atoms],
            &ws.rs[..a_cnt * m],
            atoms,
            a_cnt,
            m,
            n_atoms,
        );

        // Per-vector selection + Cholesky update + solve + residual
        // refresh, one shard per active vector. Every mutable buffer below
        // is per-vector (indexed by `bi`), so shards touch disjoint state;
        // the shared inputs (the correlation snapshot, the dictionary, the
        // originals `xs`) are frozen for the iteration — the computation
        // per vector is the exact sequential sequence, whatever the thread
        // count.
        {
            let pool = ws.pool.clone();
            let active: &[usize] = &ws.active;
            let corr: &[f32] = &ws.corr;
            let sel_ptr = SendPtr::new(ws.sel.as_mut_ptr());
            let mask_ptr = SendPtr::new(ws.mask.as_mut_ptr());
            let done_ptr = SendPtr::new(ws.done.as_mut_ptr());
            let chol_ptr = SendPtr::new(ws.chol.as_mut_ptr());
            let alpha_ptr = SendPtr::new(ws.alpha.as_mut_ptr());
            let y_ptr = SendPtr::new(ws.y.as_mut_ptr());
            let z_ptr = SendPtr::new(ws.z.as_mut_ptr());
            let b_ptr = SendPtr::new(ws.b.as_mut_ptr());
            let r_ptr = SendPtr::new(ws.r.as_mut_ptr());
            pool.parallel_for(a_cnt, move |ai| {
                let bi = active[ai];
                // SAFETY: each shard owns exactly one (ai, bi) pair and
                // every view below is that pair's private stripe.
                let sel = unsafe { &mut *sel_ptr.get().add(bi) };
                let mask = unsafe {
                    std::slice::from_raw_parts_mut(mask_ptr.get().add(bi * n_atoms), n_atoms)
                };
                let done = unsafe { &mut *done_ptr.get().add(bi) };
                let chol = unsafe {
                    std::slice::from_raw_parts_mut(
                        chol_ptr.get().add(bi * s_cap * s_cap),
                        s_cap * s_cap,
                    )
                };
                let alpha =
                    unsafe { std::slice::from_raw_parts_mut(alpha_ptr.get().add(bi * s_cap), s_cap) };
                let yv = unsafe { std::slice::from_raw_parts_mut(y_ptr.get().add(bi * s_cap), s_cap) };
                let z = unsafe { std::slice::from_raw_parts_mut(z_ptr.get().add(bi * s_cap), s_cap) };
                let bcol = unsafe { std::slice::from_raw_parts_mut(b_ptr.get().add(bi * s_cap), s_cap) };
                let r = unsafe { std::slice::from_raw_parts_mut(r_ptr.get().add(bi * m), m) };
                let x = &xs[bi * m..(bi + 1) * m];
                let corr_row = &corr[ai * n_atoms..(ai + 1) * n_atoms];

                let i = sel.len();
                let mut best = usize::MAX;
                let mut best_abs = -1.0f32;
                for n in 0..n_atoms {
                    let a = corr_row[n].abs();
                    // improvement test first (as in the sequential scan),
                    // then the O(1) bitmask — same selection as the old
                    // O(s) `sel.contains` scan, bit for bit
                    if a > best_abs && !mask[n] {
                        best_abs = a;
                        best = n;
                    }
                }
                if best == usize::MAX {
                    *done = true; // dictionary exhausted
                    return;
                }
                let aj = &atoms[best * m..(best + 1) * m];

                // Gram column against the current selection.
                for (k, &p) in sel.iter().enumerate() {
                    bcol[k] = dot(&atoms[p * m..(p + 1) * m], aj);
                }
                for k in 0..i {
                    let mut w = bcol[k];
                    for l in 0..k {
                        w -= chol[k * s_cap + l] * chol[i * s_cap + l];
                    }
                    chol[i * s_cap + k] = w / chol[k * s_cap + k];
                }
                let mut diag = 1.0f32;
                for l in 0..i {
                    diag -= chol[i * s_cap + l] * chol[i * s_cap + l];
                }
                if diag <= 1e-10 {
                    *done = true; // atom numerically in span of selection
                    return;
                }
                chol[i * s_cap + i] = diag.sqrt();
                sel.push(best);
                mask[best] = true;
                alpha[i] = dot(aj, x);

                // Solve L z = alpha, then Lᵀ y = z.
                let k_sel = i + 1;
                for k in 0..k_sel {
                    let mut zv = alpha[k];
                    for l in 0..k {
                        zv -= chol[k * s_cap + l] * z[l];
                    }
                    z[k] = zv / chol[k * s_cap + k];
                }
                for k in (0..k_sel).rev() {
                    let mut val = z[k];
                    for l in k + 1..k_sel {
                        val -= chol[l * s_cap + k] * yv[l];
                    }
                    yv[k] = val / chol[k * s_cap + k];
                }

                // residual refresh: r = x − Σ y_k a_k
                r.copy_from_slice(x);
                for (k, &p) in sel.iter().enumerate() {
                    axpy(r, -yv[k], &atoms[p * m..(p + 1) * m]);
                }
            });
        }
    }

    let codes = (0..batch)
        .map(|bi| {
            let k = ws.sel[bi].len();
            SparseCode {
                idx: ws.sel[bi].iter().map(|&p| p as u16).collect(),
                val: ws.y[bi * s_cap..bi * s_cap + k].to_vec(),
            }
        })
        .collect();
    ws.shrink(batch, n_atoms, m, s_cap);
    codes
}

/// Convenience wrapper allocating its own workspace (tests / cold paths).
pub fn omp_encode_batch_alloc(
    atoms: &[f32],
    n_atoms: usize,
    m: usize,
    xs: &[f32],
    batch: usize,
    s_max: usize,
    delta: f32,
) -> Vec<SparseCode> {
    let mut ws = BatchOmpWorkspace::new();
    omp_encode_batch(atoms, n_atoms, m, xs, batch, s_max, delta, &mut ws)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::omp::{omp_encode_alloc, rel_error};
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    fn random_unit_atoms(rng: &mut Rng, n: usize, m: usize) -> Vec<f32> {
        let mut atoms = rng.normal_vec(n * m);
        for a in atoms.chunks_mut(m) {
            let nrm = norm2(a).max(1e-12);
            a.iter_mut().for_each(|x| *x /= nrm);
        }
        atoms
    }

    #[test]
    fn batch_matches_sequential_vector_for_vector() {
        // The core parity property: not merely close — bit-identical codes.
        Prop::new(48).check("omp_batch_parity", |rng, size| {
            let m = 8 + (size % 3) * 8;
            let n = 4 * m;
            let s = 1 + rng.below(6);
            let delta = if rng.below(2) == 0 { 0.0 } else { 0.4 };
            let batch = 1 + rng.below(6);
            let atoms = random_unit_atoms(rng, n, m);
            let xs = rng.normal_vec(batch * m);
            let codes = omp_encode_batch_alloc(&atoms, n, m, &xs, batch, s, delta);
            if codes.len() != batch {
                return Err(format!("{} codes for batch {batch}", codes.len()));
            }
            for bi in 0..batch {
                let solo = omp_encode_alloc(&atoms, n, m, &xs[bi * m..(bi + 1) * m], s, delta);
                if codes[bi].idx != solo.idx {
                    return Err(format!(
                        "vec {bi}: idx {:?} != {:?}",
                        codes[bi].idx, solo.idx
                    ));
                }
                if codes[bi].val != solo.val {
                    return Err(format!(
                        "vec {bi}: val {:?} != {:?}",
                        codes[bi].val, solo.val
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn batch_encoder_is_bitwise_identical_at_every_thread_count() {
        // Exec-layer determinism: the same inputs through workspaces pinned
        // to 1-, 2- and 4-thread pools produce identical codes — and all of
        // them equal the sequential encoder.
        let mut rng = Rng::new(41);
        let (m, n, s, batch) = (16usize, 128usize, 4usize, 13usize);
        let atoms = random_unit_atoms(&mut rng, n, m);
        let xs = rng.normal_vec(batch * m);
        let runs: Vec<Vec<SparseCode>> = [1usize, 2, 4]
            .iter()
            .map(|&t| {
                let mut ws =
                    BatchOmpWorkspace::with_pool(std::sync::Arc::new(crate::exec::ExecPool::new(t)));
                omp_encode_batch(&atoms, n, m, &xs, batch, s, 0.0, &mut ws)
            })
            .collect();
        for bi in 0..batch {
            let solo = omp_encode_alloc(&atoms, n, m, &xs[bi * m..(bi + 1) * m], s, 0.0);
            for (ri, run) in runs.iter().enumerate() {
                assert_eq!(run[bi].idx, solo.idx, "T-run {ri} vec {bi}: indices diverged");
                assert_eq!(run[bi].val, solo.val, "T-run {ri} vec {bi}: values diverged");
            }
        }
    }

    #[test]
    fn workspace_reuse_across_shapes() {
        // One workspace, three calls with different (batch, N, m, s): the
        // monotone-growth buffers must not leak state between calls.
        let mut ws = BatchOmpWorkspace::new();
        let mut rng = Rng::new(17);
        for &(batch, n, m, s) in &[(6usize, 64usize, 16usize, 4usize), (2, 128, 8, 6), (9, 32, 24, 2)] {
            let atoms = random_unit_atoms(&mut rng, n, m);
            let xs = rng.normal_vec(batch * m);
            let codes = omp_encode_batch(&atoms, n, m, &xs, batch, s, 0.0, &mut ws);
            for bi in 0..batch {
                let solo = omp_encode_alloc(&atoms, n, m, &xs[bi * m..(bi + 1) * m], s, 0.0);
                assert_eq!(codes[bi].idx, solo.idx, "batch={batch} n={n} m={m} s={s}");
                assert_eq!(codes[bi].val, solo.val, "batch={batch} n={n} m={m} s={s}");
            }
        }
    }

    #[test]
    fn workspace_releases_overgrown_scratch() {
        // The attend-scratch policy applied to the encoder: a one-off giant
        // batch must not pin its high-water mark for the workspace's life.
        // After a small follow-up call, every sized buffer's capacity is
        // back within the policy bound (4× that call's need).
        let mut ws = BatchOmpWorkspace::new();
        let mut rng = Rng::new(3);
        let (n, m, s) = (64usize, 16usize, 4usize);
        let atoms = random_unit_atoms(&mut rng, n, m);
        let big = 128usize;
        let xs = rng.normal_vec(big * m);
        let _ = omp_encode_batch(&atoms, n, m, &xs, big, s, 0.0, &mut ws);
        assert!(ws.corr.capacity() >= big * n, "big call must have grown corr");
        assert!(ws.chol.capacity() >= big * s * s, "big call must have grown chol");

        let small = 2usize;
        let codes = omp_encode_batch(&atoms, n, m, &xs[..small * m], small, s, 0.0, &mut ws);
        assert_eq!(codes.len(), small);
        let bound = |need: usize| need * SCRATCH_SHRINK_FACTOR;
        assert!(ws.corr.capacity() <= bound(small * n), "corr still pinned: {}", ws.corr.capacity());
        assert!(ws.mask.capacity() <= bound(small * n), "mask still pinned: {}", ws.mask.capacity());
        assert!(ws.r.capacity() <= bound(small * m), "r still pinned: {}", ws.r.capacity());
        assert!(ws.rs.capacity() <= bound(small * m), "rs still pinned: {}", ws.rs.capacity());
        assert!(
            ws.chol.capacity() <= bound(small * s * s),
            "chol still pinned: {}",
            ws.chol.capacity()
        );
        assert!(ws.y.capacity() <= bound(small * s), "y still pinned: {}", ws.y.capacity());
        assert!(ws.sel.capacity() <= bound(small), "sel still pinned: {}", ws.sel.capacity());

        // and the shrunken workspace still encodes correctly (ensure regrows)
        let codes = omp_encode_batch(&atoms, n, m, &xs, big, s, 0.0, &mut ws);
        for bi in (0..big).step_by(37) {
            let solo = omp_encode_alloc(&atoms, n, m, &xs[bi * m..(bi + 1) * m], s, 0.0);
            assert_eq!(codes[bi].idx, solo.idx);
            assert_eq!(codes[bi].val, solo.val);
        }
    }

    #[test]
    fn zero_and_sparse_vectors_in_one_batch() {
        // A zero vector (terminates before iteration 1), an exactly-sparse
        // vector (terminates early under delta), and a dense vector must
        // coexist: per-vector termination, shared GEMM.
        let mut rng = Rng::new(5);
        let (m, n) = (16, 64);
        let atoms = random_unit_atoms(&mut rng, n, m);
        let mut xs = vec![0.0f32; 3 * m];
        // vec 0: zero. vec 1: 1-sparse in the dictionary. vec 2: dense.
        xs[m..2 * m].copy_from_slice(&atoms[7 * m..8 * m]);
        let dense = rng.normal_vec(m);
        xs[2 * m..3 * m].copy_from_slice(&dense);
        let codes = omp_encode_batch_alloc(&atoms, n, m, &xs, 3, 4, 0.01);
        assert_eq!(codes[0].nnz(), 0);
        assert!(codes[1].nnz() >= 1);
        assert_eq!(codes[1].idx[0], 7);
        assert!(rel_error(&atoms, m, &xs[m..2 * m], &codes[1]) < 1e-3);
        assert!(codes[2].nnz() >= 1);
    }

    #[test]
    fn empty_batch_is_fine() {
        let atoms = vec![1.0, 0.0, 0.0, 1.0];
        let codes = omp_encode_batch_alloc(&atoms, 2, 2, &[], 0, 4, 0.0);
        assert!(codes.is_empty());
    }
}
