//! Orthogonal Matching Pursuit — the L3 hot-path implementation.
//!
//! Cholesky-update formulation ("v0" of Zhu, Chen & Wu 2020, the variant the
//! paper adopts): the Gram matrix of the selected atoms is maintained as a
//! lower-triangular Cholesky factor updated in O(i²) per iteration, so one
//! vector costs O(s·N·m) for correlations (the dominant term, matching the
//! paper's latency analysis) plus O(s³) for the solves.
//!
//! Supports the paper's two modes: fixed sparsity `s`, and error-threshold
//! early termination (`delta` > 0, §4.2.1 — the greedy prefix property
//! makes early stopping equivalent to having asked for fewer atoms).

pub mod batch;
pub mod gram;

pub use batch::{omp_encode_batch, omp_encode_batch_alloc, BatchOmpWorkspace};
pub use gram::{omp_encode_batch_gram, omp_encode_batch_gram_alloc};

use crate::tensor::{axpy, dot, norm2};

/// True when the process opted into the precomputed-Gram Batch-OMP encode
/// tier: `--gram-omp` on any CLI subcommand, or `LEXICO_GRAM_OMP` set to
/// anything other than empty/`0`. Mirrors the fast-math tier's opt-in
/// (DESIGN.md §10): the canonical encoder stays the default. Cached after
/// the first read — consumers snapshot it at construction time (see
/// `LexicoCache::new`), so the hot paths never issue env syscalls.
pub fn gram_omp_requested() -> bool {
    static REQUESTED: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *REQUESTED.get_or_init(|| match std::env::var("LEXICO_GRAM_OMP") {
        Ok(v) => {
            let v = v.trim();
            !v.is_empty() && v != "0"
        }
        Err(_) => false,
    })
}

/// Result of sparse-coding one vector.
#[derive(Clone, Debug, Default)]
pub struct SparseCode {
    pub idx: Vec<u16>,
    pub val: Vec<f32>,
}

impl SparseCode {
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }
}

/// Reusable workspace so the decode hot loop never allocates.
pub struct OmpWorkspace {
    corr: Vec<f32>,    // [N] correlation scratch
    chol: Vec<f32>,    // [s*s] lower-triangular L
    alpha: Vec<f32>,   // [s] D_Sᵀ x
    z: Vec<f32>,       // [s] forward-solve scratch
    y: Vec<f32>,       // [s] coefficients
    r: Vec<f32>,       // [m] residual
    b: Vec<f32>,       // [s] new Gram column
    sel: Vec<usize>,   // selected atom ids
    mask: Vec<bool>,   // [N] selected-atom bitmask (O(1) argmax mask scan)
}

impl OmpWorkspace {
    pub fn new(n_atoms: usize, m: usize, s_max: usize) -> Self {
        OmpWorkspace {
            corr: vec![0.0; n_atoms],
            chol: vec![0.0; s_max * s_max],
            alpha: vec![0.0; s_max],
            z: vec![0.0; s_max],
            y: vec![0.0; s_max],
            r: vec![0.0; m],
            b: vec![0.0; s_max],
            sel: Vec::with_capacity(s_max),
            mask: vec![false; n_atoms],
        }
    }

    fn ensure(&mut self, n_atoms: usize, m: usize, s_max: usize) {
        // Each buffer's growth is independent: a call mix that enlarges one
        // dimension after a warmup on another must never leave a companion
        // buffer behind (regression: alpha/z/y/b previously grew only when
        // `chol` did, coupling the s-sized buffers to chol's history).
        if self.corr.len() < n_atoms {
            self.corr.resize(n_atoms, 0.0);
        }
        if self.mask.len() < n_atoms {
            self.mask.resize(n_atoms, false);
        }
        if self.r.len() < m {
            self.r.resize(m, 0.0);
        }
        if self.chol.len() < s_max * s_max {
            self.chol.resize(s_max * s_max, 0.0);
        }
        if self.alpha.len() < s_max {
            self.alpha.resize(s_max, 0.0);
        }
        if self.z.len() < s_max {
            self.z.resize(s_max, 0.0);
        }
        if self.y.len() < s_max {
            self.y.resize(s_max, 0.0);
        }
        if self.b.len() < s_max {
            self.b.resize(s_max, 0.0);
        }
    }
}

/// Sparse-code `x` [m] over `atoms` [N, m] (atom-major, unit-norm rows).
///
/// Runs at most `s_max` iterations; if `delta > 0`, stops once
/// `‖x − Dy‖ ≤ delta·‖x‖`. Returns (indices, coefficients) of equal length.
pub fn omp_encode(
    atoms: &[f32],
    n_atoms: usize,
    m: usize,
    x: &[f32],
    s_max: usize,
    delta: f32,
    ws: &mut OmpWorkspace,
) -> SparseCode {
    debug_assert_eq!(atoms.len(), n_atoms * m);
    debug_assert_eq!(x.len(), m);
    ws.ensure(n_atoms, m, s_max);
    ws.sel.clear();
    ws.mask[..n_atoms].fill(false);
    ws.r[..m].copy_from_slice(x);
    let norm_x = norm2(x);
    let stop = (delta * norm_x).max(1e-12);
    let s_max = s_max.min(n_atoms).min(m.max(1) * 4); // defensive cap

    for i in 0..s_max {
        let r = &ws.r[..m];
        if norm2(r) <= stop {
            break;
        }
        // correlation step: c = D_atoms · r  (the O(N·m) hot loop).
        // Already-selected atoms are masked out of the scan: the residual is
        // orthogonal to them only up to rounding, so an unmasked argmax can
        // re-pick one and would otherwise end the pursuit with sparsity
        // budget left on the table.
        let mut best = usize::MAX;
        let mut best_abs = -1.0f32;
        for n in 0..n_atoms {
            let c = dot(&atoms[n * m..(n + 1) * m], r);
            let a = c.abs();
            // improvement test first, then the O(1) bitmask lookup — same
            // selection as the old O(s) `sel.contains` scan, bit for bit
            // (the mask is exactly the membership test it replaces)
            if a > best_abs && !ws.mask[n] {
                best_abs = a;
                best = n;
            }
        }
        if best == usize::MAX {
            break; // every atom selected: dictionary exhausted
        }
        let aj = &atoms[best * m..(best + 1) * m];

        // Cholesky update: b_k = <a_sel[k], a_j>; w = L⁻¹ b (forward sub);
        // L[i][..i] = w, L[i][i] = sqrt(1 − wᵀw) (unit-norm atoms).
        for (k, &p) in ws.sel.iter().enumerate() {
            ws.b[k] = dot(&atoms[p * m..(p + 1) * m], aj);
        }
        for k in 0..i {
            let mut w = ws.b[k];
            for l in 0..k {
                w -= ws.chol[k * s_max + l] * ws.chol[i * s_max + l];
            }
            ws.chol[i * s_max + k] = w / ws.chol[k * s_max + k];
        }
        let mut diag = 1.0;
        for l in 0..i {
            diag -= ws.chol[i * s_max + l] * ws.chol[i * s_max + l];
        }
        if diag <= 1e-10 {
            break; // atom (numerically) in span of selection: stop
        }
        ws.chol[i * s_max + i] = diag.sqrt();
        ws.sel.push(best);
        ws.mask[best] = true;
        ws.alpha[i] = dot(aj, x);

        // Solve L z = alpha, then Lᵀ y = z.
        let k_sel = ws.sel.len();
        for k in 0..k_sel {
            let mut z = ws.alpha[k];
            for l in 0..k {
                z -= ws.chol[k * s_max + l] * ws.z[l];
            }
            ws.z[k] = z / ws.chol[k * s_max + k];
        }
        for k in (0..k_sel).rev() {
            let mut y = ws.z[k];
            for l in k + 1..k_sel {
                y -= ws.chol[l * s_max + k] * ws.y[l];
            }
            ws.y[k] = y / ws.chol[k * s_max + k];
        }

        // residual refresh: r = x − Σ y_k a_k
        ws.r[..m].copy_from_slice(x);
        for (k, &p) in ws.sel.iter().enumerate() {
            axpy(&mut ws.r[..m], -ws.y[k], &atoms[p * m..(p + 1) * m]);
        }
    }

    SparseCode {
        idx: ws.sel.iter().map(|&p| p as u16).collect(),
        val: ws.y[..ws.sel.len()].to_vec(),
    }
}

/// Convenience wrapper allocating its own workspace (tests / cold paths).
pub fn omp_encode_alloc(
    atoms: &[f32],
    n_atoms: usize,
    m: usize,
    x: &[f32],
    s_max: usize,
    delta: f32,
) -> SparseCode {
    let mut ws = OmpWorkspace::new(n_atoms, m, s_max);
    omp_encode(atoms, n_atoms, m, x, s_max, delta, &mut ws)
}

/// Dense reconstruction helper.
pub fn reconstruct(atoms: &[f32], m: usize, code: &SparseCode, out: &mut [f32]) {
    out.fill(0.0);
    for (j, &id) in code.idx.iter().enumerate() {
        axpy(out, code.val[j], &atoms[id as usize * m..(id as usize + 1) * m]);
    }
}

/// Sign-tier finalize pass (DESIGN.md §14): collapse a pursuit's
/// coefficients to `±α` with `α = f16(mean |val|)`, folding every
/// magnitude into one per-row scale before the code reaches storage.
///
/// Runs after any encode tier (canonical, batch, or Gram pursuit) and
/// before the cache quantizes the row, so the stored sign bitmap + scale
/// reproduce exactly these values. The pass is idempotent in exact
/// f32/f16 arithmetic: the n-fold sum of one f16-representable `α` is
/// exact in f32 (α's 11-bit significand plus log2(n) carry bits fit in
/// f32's 24), the division by n rounds that exact product back to `α`,
/// and re-rounding an f16 value to f16 is the identity — so re-encoding
/// a finalized code changes nothing, bit for bit.
pub fn sign_finalize(code: &mut SparseCode) {
    use crate::sparse::fp8::{f16_to_f32, f32_to_f16};
    if code.val.is_empty() {
        return;
    }
    let mut sum = 0.0f32;
    for &v in &code.val {
        sum += v.abs();
    }
    let alpha = f16_to_f32(f32_to_f16(sum / code.val.len() as f32));
    for v in &mut code.val {
        *v = if v.is_sign_negative() { -alpha } else { alpha };
    }
}

/// Relative ℓ2 reconstruction error.
pub fn rel_error(atoms: &[f32], m: usize, x: &[f32], code: &SparseCode) -> f32 {
    let mut recon = vec![0.0; m];
    reconstruct(atoms, m, code, &mut recon);
    let mut err = 0.0;
    for i in 0..m {
        let d = x[i] - recon[i];
        err += d * d;
    }
    (err.sqrt() as f32) / norm2(x).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::Prop;
    use crate::util::rng::Rng;

    fn random_unit_atoms(rng: &mut Rng, n: usize, m: usize) -> Vec<f32> {
        let mut atoms = rng.normal_vec(n * m);
        for a in atoms.chunks_mut(m) {
            let nrm = norm2(a).max(1e-12);
            a.iter_mut().for_each(|x| *x /= nrm);
        }
        atoms
    }

    #[test]
    fn recovers_exact_sparse_signal() {
        // x built from k atoms of a well-separated dictionary is recovered
        // exactly (support + coefficients) when k is small.
        Prop::new(48).check("omp_exact_recovery", |rng, size| {
            let m = 16 + (size % 3) * 8;
            let n = 4 * m;
            let atoms = random_unit_atoms(rng, n, m);
            let k = 1 + rng.below(3);
            let mut x = vec![0.0; m];
            let mut truth = Vec::new();
            for _ in 0..k {
                let id = rng.below(n);
                let c = rng.range_f32(0.5, 2.0) * if rng.below(2) == 0 { 1.0 } else { -1.0 };
                truth.push(id);
                axpy(&mut x, c, &atoms[id * m..(id + 1) * m]);
            }
            let code = omp_encode_alloc(&atoms, n, m, &x, k, 0.0);
            let err = rel_error(&atoms, m, &x, &code);
            if err < 1e-3 {
                Ok(())
            } else {
                Err(format!("k={k} err={err}"))
            }
        });
    }

    #[test]
    fn residual_decreases_monotonically() {
        Prop::new(32).check("omp_monotone", |rng, _| {
            let (m, n) = (32, 128);
            let atoms = random_unit_atoms(rng, n, m);
            let x = rng.normal_vec(m);
            let mut prev = f32::INFINITY;
            for s in 1..=8 {
                let code = omp_encode_alloc(&atoms, n, m, &x, s, 0.0);
                let err = rel_error(&atoms, m, &x, &code);
                if err > prev + 1e-4 {
                    return Err(format!("err rose at s={s}: {prev} → {err}"));
                }
                prev = err;
            }
            Ok(())
        });
    }

    #[test]
    fn residual_orthogonal_to_selection() {
        let mut rng = Rng::new(11);
        let (m, n, s) = (32, 256, 6);
        let atoms = random_unit_atoms(&mut rng, n, m);
        let x = rng.normal_vec(m);
        let code = omp_encode_alloc(&atoms, n, m, &x, s, 0.0);
        let mut recon = vec![0.0; m];
        reconstruct(&atoms, m, &code, &mut recon);
        let r: Vec<f32> = x.iter().zip(&recon).map(|(a, b)| a - b).collect();
        for &id in &code.idx {
            let c = dot(&r, &atoms[id as usize * m..(id as usize + 1) * m]);
            assert!(c.abs() < 1e-3, "residual not ⊥ atom {id}: {c}");
        }
    }

    #[test]
    fn threshold_mode_stops_early_with_greedy_prefix() {
        Prop::new(24).check("omp_threshold", |rng, _| {
            let (m, n) = (32, 128);
            let atoms = random_unit_atoms(rng, n, m);
            let x = rng.normal_vec(m);
            let full = omp_encode_alloc(&atoms, n, m, &x, 12, 0.0);
            let thr = omp_encode_alloc(&atoms, n, m, &x, 12, 0.5);
            // prefix property: thresholded run = prefix of the full run
            if thr.idx[..] != full.idx[..thr.nnz()] {
                return Err(format!("not a prefix: {:?} vs {:?}", thr.idx, full.idx));
            }
            let err = rel_error(&atoms, m, &x, &thr);
            // it stopped because the error bound was met (or ran out of iters)
            if thr.nnz() < 12 && err > 0.5 + 1e-3 {
                return Err(format!("stopped early but err {err} > δ"));
            }
            Ok(())
        });
    }

    #[test]
    fn uses_full_sparsity_budget() {
        // Regression: an argmax landing on an already-selected atom must be
        // masked out of the scan, not end the pursuit with sparsity budget
        // left over. A dense random target can't be represented early, so
        // the pursuit must either spend all s iterations or have converged.
        Prop::new(64).check("omp_full_budget", |rng, _| {
            let (m, n, s) = (16, 64, 8);
            let atoms = random_unit_atoms(rng, n, m);
            let x = rng.normal_vec(m);
            let code = omp_encode_alloc(&atoms, n, m, &x, s, 0.0);
            for (j, &id) in code.idx.iter().enumerate() {
                if code.idx[..j].contains(&id) {
                    return Err(format!("atom {id} selected twice: {:?}", code.idx));
                }
            }
            if code.nnz() == s {
                return Ok(());
            }
            let err = rel_error(&atoms, m, &x, &code);
            if err < 1e-4 {
                Ok(())
            } else {
                Err(format!("stopped at nnz={} with err={err}", code.nnz()))
            }
        });
    }

    #[test]
    fn workspace_buffers_grow_independently_across_shape_cycles() {
        // Regression for the coupled-growth bug: `alpha`/`z`/`y`/`b` used to
        // resize only inside the `chol` growth branch, so their sizes were a
        // function of chol's history rather than the current call. One
        // workspace cycled through adversarial (n, m, s) shapes — each
        // dimension growing after a warmup on the others — must keep every
        // call bit-identical to a fresh workspace.
        let mut ws = OmpWorkspace::new(8, 4, 2);
        let mut rng = Rng::new(33);
        for &(n, m, s) in &[
            (8usize, 4usize, 2usize), // matches construction
            (64, 4, 2),               // n grows alone
            (64, 32, 2),              // m grows alone
            (16, 8, 12),              // s grows while n/m shrink
            (128, 16, 5),             // n grows again, s shrinks
            (32, 48, 16),             // m and s grow together
        ] {
            let atoms = random_unit_atoms(&mut rng, n, m);
            let x = rng.normal_vec(m);
            let code = omp_encode(&atoms, n, m, &x, s, 0.0, &mut ws);
            let solo = omp_encode_alloc(&atoms, n, m, &x, s, 0.0);
            assert_eq!(code.idx, solo.idx, "idx diverged at n={n} m={m} s={s}");
            assert_eq!(code.val, solo.val, "val diverged at n={n} m={m} s={s}");
        }
    }

    #[test]
    fn sign_finalize_is_idempotent_and_matches_slab_quantization() {
        use crate::sparse::{CoefMode, CsrSlab};
        Prop::new(32).check("sign_finalize", |rng, _| {
            let n = 1 + rng.below(12);
            let mut code = SparseCode {
                idx: (0..n as u16).collect(),
                val: rng.normal_vec(n),
            };
            sign_finalize(&mut code);
            // all magnitudes equal, signs preserved from the raw pursuit
            let a = code.val[0].abs();
            for &v in &code.val {
                if v.abs().to_bits() != a.to_bits() {
                    return Err(format!("unequal magnitude {v} vs {a}"));
                }
            }
            // idempotent: finalizing again must not move a single bit
            let once = code.val.clone();
            sign_finalize(&mut code);
            if code.val != once {
                return Err("second finalize changed values".into());
            }
            // and the sign slab stores exactly these values back
            let mut slab = CsrSlab::new(CoefMode::Sign);
            slab.push_f32(&code.idx, &code.val);
            let mut dec = Vec::new();
            slab.row_values(0, &mut dec);
            for (got, want) in dec.iter().zip(&code.val) {
                if got.to_bits() != want.to_bits() {
                    return Err(format!("slab round-trip moved {want} → {got}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn zero_vector_yields_empty_code() {
        let mut rng = Rng::new(5);
        let atoms = random_unit_atoms(&mut rng, 64, 16);
        let x = vec![0.0; 16];
        let code = omp_encode_alloc(&atoms, 64, 16, &x, 4, 0.0);
        assert_eq!(code.nnz(), 0);
    }

    #[test]
    fn orthonormal_dictionary_is_exact_at_s_eq_m() {
        // D = I (m atoms): OMP with s=m must reconstruct exactly.
        let m = 8;
        let mut atoms = vec![0.0; m * m];
        for i in 0..m {
            atoms[i * m + i] = 1.0;
        }
        let mut rng = Rng::new(9);
        let x = rng.normal_vec(m);
        let code = omp_encode_alloc(&atoms, m, m, &x, m, 0.0);
        assert!(rel_error(&atoms, m, &x, &code) < 1e-5);
    }
}
