//! Little-endian wire helpers shared by every serialized residency
//! artifact (page files, session snapshots). Scalars and raw slices carry
//! no framing; the `*s` variants are u32-length-prefixed for self-framing
//! snapshot fields. Floats travel as IEEE-754 bits (`to_bits`/`from_bits`),
//! so encode → decode is bit-exact by construction.

pub fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f32(buf: &mut Vec<u8>, v: f32) {
    put_u32(buf, v.to_bits());
}

/// Raw (unframed) u16 slice — caller must know the count to read it back.
pub fn put_u16_slice_raw(buf: &mut Vec<u8>, s: &[u16]) {
    for &v in s {
        put_u16(buf, v);
    }
}

/// Raw (unframed) u32 slice — caller must know the count to read it back.
pub fn put_u32_slice_raw(buf: &mut Vec<u8>, s: &[u32]) {
    for &v in s {
        put_u32(buf, v);
    }
}

/// Raw (unframed) byte slice — caller must know the count to read it
/// back (the sign-tier bitmap payload in page format v2).
pub fn put_u8_slice_raw(buf: &mut Vec<u8>, s: &[u8]) {
    buf.extend_from_slice(s);
}

/// u32-length-prefixed u16 slice.
pub fn put_u16s(buf: &mut Vec<u8>, s: &[u16]) {
    put_u32(buf, s.len() as u32);
    put_u16_slice_raw(buf, s);
}

/// u32-length-prefixed u32 slice.
pub fn put_u32s(buf: &mut Vec<u8>, s: &[u32]) {
    put_u32(buf, s.len() as u32);
    put_u32_slice_raw(buf, s);
}

/// u32-length-prefixed f32 slice (stored as bits — exact).
pub fn put_f32s(buf: &mut Vec<u8>, s: &[f32]) {
    put_u32(buf, s.len() as u32);
    for &v in s {
        put_f32(buf, v);
    }
}

/// u32-length-prefixed byte string.
pub fn put_bytes(buf: &mut Vec<u8>, s: &[u8]) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s);
}

/// u32-length-prefixed UTF-8 string.
pub fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_bytes(buf, s.as_bytes());
}

/// Bounds-checked cursor over a byte buffer. Every `take_*` fails with a
/// plain message instead of panicking, so a truncated or corrupt artifact
/// surfaces as a session error, never a server crash.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.remaining() < n {
            return Err(format!(
                "truncated: wanted {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn take_u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    pub fn take_u16(&mut self) -> Result<u16, String> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn take_u32(&mut self) -> Result<u32, String> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn take_u64(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    pub fn take_f32(&mut self) -> Result<f32, String> {
        Ok(f32::from_bits(self.take_u32()?))
    }

    /// Raw (unframed) u16 slice of known count.
    pub fn take_u16_slice_raw(&mut self, n: usize) -> Result<Vec<u16>, String> {
        let b = self.take(n * 2)?;
        Ok(b.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect())
    }

    /// Raw (unframed) u32 slice of known count.
    pub fn take_u32_slice_raw(&mut self, n: usize) -> Result<Vec<u32>, String> {
        let b = self.take(n * 4)?;
        Ok(b.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    /// Raw (unframed) byte slice of known count.
    pub fn take_u8_slice_raw(&mut self, n: usize) -> Result<Vec<u8>, String> {
        Ok(self.take(n)?.to_vec())
    }

    fn take_len(&mut self) -> Result<usize, String> {
        let n = self.take_u32()? as usize;
        // a length prefix can never exceed what's left in the buffer: catch
        // corrupt lengths before attempting a huge allocation
        if n > self.remaining() {
            return Err(format!("corrupt length prefix {n} (only {} bytes left)", self.remaining()));
        }
        Ok(n)
    }

    pub fn take_u16s(&mut self) -> Result<Vec<u16>, String> {
        let n = self.take_u32()? as usize;
        self.take_u16_slice_raw(n)
    }

    pub fn take_u32s(&mut self) -> Result<Vec<u32>, String> {
        let n = self.take_u32()? as usize;
        self.take_u32_slice_raw(n)
    }

    pub fn take_f32s(&mut self) -> Result<Vec<f32>, String> {
        let n = self.take_u32()? as usize;
        let b = self.take(n * 4)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
            .collect())
    }

    pub fn take_bytes(&mut self) -> Result<Vec<u8>, String> {
        let n = self.take_len()?;
        Ok(self.take(n)?.to_vec())
    }

    pub fn take_str(&mut self) -> Result<String, String> {
        let b = self.take_bytes()?;
        String::from_utf8(b).map_err(|_| "invalid utf-8 in string field".into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_slices_round_trip_exactly() {
        let mut buf = Vec::new();
        put_u16(&mut buf, 0xbeef);
        put_u32(&mut buf, 0xdead_beef);
        put_u64(&mut buf, 0x0123_4567_89ab_cdef);
        put_f32(&mut buf, -0.0); // signed zero survives bit transport
        put_f32(&mut buf, f32::NAN);
        put_u16s(&mut buf, &[1, 2, 3]);
        put_u32s(&mut buf, &[]);
        put_f32s(&mut buf, &[1.5, -2.25e-30]);
        put_str(&mut buf, "sess-α");
        let mut r = Reader::new(&buf);
        assert_eq!(r.take_u16().unwrap(), 0xbeef);
        assert_eq!(r.take_u32().unwrap(), 0xdead_beef);
        assert_eq!(r.take_u64().unwrap(), 0x0123_4567_89ab_cdef);
        assert_eq!(r.take_f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert!(r.take_f32().unwrap().is_nan());
        assert_eq!(r.take_u16s().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.take_u32s().unwrap(), Vec::<u32>::new());
        let f = r.take_f32s().unwrap();
        assert_eq!(f[0].to_bits(), 1.5f32.to_bits());
        assert_eq!(f[1].to_bits(), (-2.25e-30f32).to_bits());
        assert_eq!(r.take_str().unwrap(), "sess-α");
        assert!(r.is_empty());
    }

    #[test]
    fn truncation_and_bad_lengths_error_instead_of_panicking() {
        let mut r = Reader::new(&[1, 2, 3]);
        assert!(r.take_u32().is_err());
        let mut buf = Vec::new();
        put_u32(&mut buf, u32::MAX); // absurd length prefix
        let mut r = Reader::new(&buf);
        assert!(r.take_bytes().is_err());
        // u16 slice with length prefix past the end
        let mut buf = Vec::new();
        put_u32(&mut buf, 9);
        put_u16(&mut buf, 7);
        let mut r = Reader::new(&buf);
        assert!(r.take_u16s().is_err());
    }
}
