//! Tiered KV residency: the on-disk page store (DESIGN.md §11).
//!
//! Sealed [`crate::cache`] pages are immutable, position-independent pairs
//! of CSR slabs — exactly the property that makes them spillable. This
//! module provides the disk half of the residency tier:
//!
//! - [`wire`]: little-endian encode/decode helpers shared by every
//!   serialized artifact (pages, session snapshots).
//! - a binary page format v2 (`encode_page`/`decode_page`): a fixed header
//!   carrying magic, version, per-side coefficient mode, row/nnz/aux counts
//!   and an FNV-1a 64 payload checksum, followed by each side's flat CSR
//!   arrays (for the sign tier: indices, packed sign bitmap, per-row f16
//!   scales, row offsets).
//! - [`PageFile`]: an append-only file of pages with an in-memory index,
//!   rebuilt by a validating scan on reopen (a torn tail from a crash
//!   mid-append is truncated away rather than poisoning the file).
//! - [`SpillStore`]: the shared, thread-safe handle sessions spill through,
//!   with cumulative spill/fault counters and the opt-in cold-tier
//!   recompression pass (drop lowest-|coef| atoms and/or tighten FP16
//!   coefficients to FP8) applied at spill time.
//!
//! Contract: without a cold tier, `fault(spill(page))` is field-for-field
//! identical to the page that was spilled, so a spilled-then-faulted
//! session's decode stream is bitwise-identical to a never-spilled one.
//! Cold-tier recompression is lossy by design and excluded from that
//! contract (tolerance goldens pin it instead).

use std::fmt;
use std::fs::{self, File, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

use crate::sparse::{CoefMode, CoefPrecision, CsrSlab};

pub mod wire;

/// Page header magic: `"LXPG"`.
pub const PAGE_MAGIC: u32 = 0x4c58_5047;
/// Page format version. v2 added per-side coefficient-mode bytes and the
/// sign-bitmap aux lengths; v1 pages (which predate the sign tier) are
/// rejected rather than silently misparsed.
pub const PAGE_VERSION: u16 = 2;
/// Fixed page header length in bytes.
pub const HEADER_LEN: usize = 36;

/// FNV-1a 64-bit hash — the page payload checksum.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Errors from the page store. `Corrupt` carries the file offset so a bad
/// page is diagnosable; both render as a plain message for session-level
/// error replies (the server never panics on a bad page file).
#[derive(Debug)]
pub enum StoreError {
    Io(std::io::Error),
    Corrupt { offset: u64, what: String },
}

impl fmt::Display for StoreError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            StoreError::Io(e) => write!(f, "page store io: {e}"),
            StoreError::Corrupt { offset, what } => {
                write!(f, "page store corrupt at offset {offset}: {what}")
            }
        }
    }
}

impl std::error::Error for StoreError {}

impl From<std::io::Error> for StoreError {
    fn from(e: std::io::Error) -> Self {
        StoreError::Io(e)
    }
}

/// Location of one page inside the page file. Self-describing (offset +
/// total length including header), so refs stay valid across process
/// restarts — the append-only file never moves a written page.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PageRef {
    pub offset: u64,
    pub len: u32,
}

fn mode_byte(m: CoefMode) -> u8 {
    match m {
        CoefMode::Fp8 => 0,
        CoefMode::Fp16 => 1,
        CoefMode::Sign => 2,
    }
}

fn byte_mode(b: u8, offset: u64) -> Result<CoefMode, StoreError> {
    match b {
        0 => Ok(CoefMode::Fp8),
        1 => Ok(CoefMode::Fp16),
        2 => Ok(CoefMode::Sign),
        _ => Err(StoreError::Corrupt {
            offset,
            what: format!("bad coefficient-mode byte {b}"),
        }),
    }
}

/// The `aux` header field for one side: the packed sign-bitmap byte count
/// (sign tier only — byte modes carry no bitmap and store 0).
fn slab_aux(s: &CsrSlab) -> usize {
    match s.precision() {
        CoefMode::Sign => s.sign_parts().1.len(),
        _ => 0,
    }
}

fn slab_payload(buf: &mut Vec<u8>, s: &CsrSlab) {
    match s.precision() {
        CoefMode::Fp8 | CoefMode::Fp16 => {
            let (idx, bits, off) = s.raw_parts();
            wire::put_u16_slice_raw(buf, idx);
            wire::put_u16_slice_raw(buf, bits);
            wire::put_u32_slice_raw(buf, off);
        }
        CoefMode::Sign => {
            let (idx, signs, scales, off) = s.sign_parts();
            wire::put_u16_slice_raw(buf, idx);
            wire::put_u8_slice_raw(buf, signs);
            wire::put_u16_slice_raw(buf, scales);
            wire::put_u32_slice_raw(buf, off);
        }
    }
}

/// Serialize a (K, V) slab pair into the page wire format.
///
/// Layout (little-endian): `magic u32 | version u16 | k_mode u8 | v_mode u8
/// | rows u32 | k_nnz u32 | v_nnz u32 | k_aux u32 | v_aux u32 | checksum
/// u64 | payload`. Per side, a byte-mode payload is the three flat arrays
/// `idx, coef_bits, row_off`; a sign-tier payload is `idx, sign bitmap
/// (aux bytes), row_scale, row_off`. The checksum is FNV-1a 64 over the
/// whole payload. Both slabs must have the same row count (a page covers
/// one token span).
pub fn encode_page(k: &CsrSlab, v: &CsrSlab) -> Vec<u8> {
    assert_eq!(k.rows(), v.rows(), "page K/V slabs must cover the same rows");
    let mut payload = Vec::with_capacity(4 * (k.nnz() + v.nnz()) + 8 * (k.rows() + 1));
    slab_payload(&mut payload, k);
    slab_payload(&mut payload, v);
    let mut buf = Vec::with_capacity(HEADER_LEN + payload.len());
    wire::put_u32(&mut buf, PAGE_MAGIC);
    wire::put_u16(&mut buf, PAGE_VERSION);
    buf.push(mode_byte(k.precision()));
    buf.push(mode_byte(v.precision()));
    wire::put_u32(&mut buf, k.rows() as u32);
    wire::put_u32(&mut buf, k.nnz() as u32);
    wire::put_u32(&mut buf, v.nnz() as u32);
    wire::put_u32(&mut buf, slab_aux(k) as u32);
    wire::put_u32(&mut buf, slab_aux(v) as u32);
    wire::put_u64(&mut buf, fnv1a64(&payload));
    buf.extend_from_slice(&payload);
    buf
}

struct PageHeader {
    k_mode: CoefMode,
    v_mode: CoefMode,
    rows: u32,
    k_nnz: u32,
    v_nnz: u32,
    k_aux: u32,
    v_aux: u32,
    checksum: u64,
}

fn side_payload_len(mode: CoefMode, nnz: usize, rows: usize, aux: usize) -> usize {
    let off = 4 * (rows + 1);
    match mode {
        CoefMode::Fp8 | CoefMode::Fp16 => 2 * nnz + 2 * nnz + off,
        CoefMode::Sign => 2 * nnz + aux + 2 * rows + off,
    }
}

impl PageHeader {
    fn payload_len(&self) -> usize {
        let rows = self.rows as usize;
        side_payload_len(self.k_mode, self.k_nnz as usize, rows, self.k_aux as usize)
            + side_payload_len(self.v_mode, self.v_nnz as usize, rows, self.v_aux as usize)
    }

    fn total_len(&self) -> usize {
        HEADER_LEN + self.payload_len()
    }
}

fn decode_header(buf: &[u8], offset: u64) -> Result<PageHeader, StoreError> {
    if buf.len() < HEADER_LEN {
        return Err(StoreError::Corrupt {
            offset,
            what: format!("truncated header ({} of {HEADER_LEN} bytes)", buf.len()),
        });
    }
    let mut r = wire::Reader::new(&buf[..HEADER_LEN]);
    let magic = r.take_u32().unwrap();
    if magic != PAGE_MAGIC {
        return Err(StoreError::Corrupt {
            offset,
            what: format!("bad magic {magic:#010x}"),
        });
    }
    let version = r.take_u16().unwrap();
    if version != PAGE_VERSION {
        return Err(StoreError::Corrupt {
            offset,
            what: format!("unsupported page version {version}"),
        });
    }
    let k_mode = byte_mode(r.take_u8().unwrap(), offset)?;
    let v_mode = byte_mode(r.take_u8().unwrap(), offset)?;
    let rows = r.take_u32().unwrap();
    let k_nnz = r.take_u32().unwrap();
    let v_nnz = r.take_u32().unwrap();
    let k_aux = r.take_u32().unwrap();
    let v_aux = r.take_u32().unwrap();
    let checksum = r.take_u64().unwrap();
    for (side, mode, aux) in [("K", k_mode, k_aux), ("V", v_mode, v_aux)] {
        if mode != CoefMode::Sign && aux != 0 {
            return Err(StoreError::Corrupt {
                offset,
                what: format!("{side} side: nonzero aux {aux} for byte-coef mode"),
            });
        }
    }
    Ok(PageHeader { k_mode, v_mode, rows, k_nnz, v_nnz, k_aux, v_aux, checksum })
}

fn decode_slab(
    r: &mut wire::Reader<'_>,
    nnz: usize,
    rows: usize,
    mode: CoefMode,
    aux: usize,
    offset: u64,
) -> Result<CsrSlab, StoreError> {
    let corrupt = |what: String| StoreError::Corrupt { offset, what };
    let idx = r.take_u16_slice_raw(nnz).map_err(&corrupt)?;
    match mode {
        CoefMode::Fp8 | CoefMode::Fp16 => {
            let bits = r.take_u16_slice_raw(nnz).map_err(&corrupt)?;
            let off = r.take_u32_slice_raw(rows + 1).map_err(&corrupt)?;
            CsrSlab::from_raw_parts(idx, bits, off, mode).map_err(&corrupt)
        }
        CoefMode::Sign => {
            let signs = r.take_u8_slice_raw(aux).map_err(&corrupt)?;
            let scales = r.take_u16_slice_raw(rows).map_err(&corrupt)?;
            let off = r.take_u32_slice_raw(rows + 1).map_err(&corrupt)?;
            CsrSlab::from_sign_parts(idx, signs, scales, off).map_err(&corrupt)
        }
    }
}

/// Decode one page produced by [`encode_page`], verifying magic, version,
/// checksum, and the CSR invariants of both slabs. `offset` is only used to
/// label errors.
pub fn decode_page(buf: &[u8], offset: u64) -> Result<(CsrSlab, CsrSlab), StoreError> {
    let h = decode_header(buf, offset)?;
    if buf.len() != h.total_len() {
        return Err(StoreError::Corrupt {
            offset,
            what: format!("length {} != header-implied {}", buf.len(), h.total_len()),
        });
    }
    let payload = &buf[HEADER_LEN..];
    let got = fnv1a64(payload);
    if got != h.checksum {
        return Err(StoreError::Corrupt {
            offset,
            what: format!("checksum mismatch (stored {:#018x}, computed {got:#018x})", h.checksum),
        });
    }
    let mut r = wire::Reader::new(payload);
    let rows = h.rows as usize;
    let k = decode_slab(&mut r, h.k_nnz as usize, rows, h.k_mode, h.k_aux as usize, offset)?;
    let v = decode_slab(&mut r, h.v_nnz as usize, rows, h.v_mode, h.v_aux as usize, offset)?;
    Ok((k, v))
}

/// Append-only file of encoded pages plus the in-memory index of every
/// page it holds. Reopening an existing file rebuilds the index with a
/// validating header scan; a torn tail (crash mid-append) is truncated.
pub struct PageFile {
    file: File,
    path: PathBuf,
    end: u64,
    index: Vec<PageRef>,
}

impl PageFile {
    /// Open (or create) the page file at `path`, scanning any existing
    /// contents to rebuild the index.
    pub fn open(path: &Path) -> Result<PageFile, StoreError> {
        let mut file = OpenOptions::new().read(true).write(true).create(true).open(path)?;
        let file_len = file.metadata()?.len();
        let mut index = Vec::new();
        let mut off = 0u64;
        let mut header = [0u8; HEADER_LEN];
        while off + HEADER_LEN as u64 <= file_len {
            file.seek(SeekFrom::Start(off))?;
            file.read_exact(&mut header)?;
            let h = match decode_header(&header, off) {
                Ok(h) => h,
                // garbage header mid-file: stop indexing here, truncate tail
                Err(_) => break,
            };
            let total = h.total_len() as u64;
            if off + total > file_len {
                break; // torn append: page body incomplete
            }
            index.push(PageRef { offset: off, len: total as u32 });
            off += total;
        }
        if off < file_len {
            file.set_len(off)?; // drop the torn tail
        }
        file.seek(SeekFrom::Start(off))?;
        Ok(PageFile { file, path: path.to_path_buf(), end: off, index })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Number of pages in the file.
    pub fn pages(&self) -> usize {
        self.index.len()
    }

    /// Total bytes of appended pages.
    pub fn bytes(&self) -> u64 {
        self.end
    }

    /// The in-memory index, in append order.
    pub fn index(&self) -> &[PageRef] {
        &self.index
    }

    /// Append one page, returning its stable ref.
    pub fn append(&mut self, k: &CsrSlab, v: &CsrSlab) -> Result<PageRef, StoreError> {
        let buf = encode_page(k, v);
        self.file.seek(SeekFrom::Start(self.end))?;
        self.file.write_all(&buf)?;
        self.file.flush()?;
        let r = PageRef { offset: self.end, len: buf.len() as u32 };
        self.end += buf.len() as u64;
        self.index.push(r);
        Ok(r)
    }

    /// Read and validate the page at `r`.
    pub fn read(&mut self, r: PageRef) -> Result<(CsrSlab, CsrSlab), StoreError> {
        if r.offset + r.len as u64 > self.end {
            return Err(StoreError::Corrupt {
                offset: r.offset,
                what: format!(
                    "page ref past end of file ({} + {} > {})",
                    r.offset, r.len, self.end
                ),
            });
        }
        let mut buf = vec![0u8; r.len as usize];
        self.file.seek(SeekFrom::Start(r.offset))?;
        self.file.read_exact(&mut buf)?;
        decode_page(&buf, r.offset)
    }
}

/// Opt-in cold-tier recompression applied at spill time: keep at most
/// `keep_atoms` per row (largest |coef| first) and/or requantize FP16
/// coefficients to FP8. Lossy — excluded from the bitwise contract.
#[derive(Clone, Copy, Debug, Default)]
pub struct ColdTier {
    pub keep_atoms: Option<usize>,
    pub to_fp8: bool,
}

impl ColdTier {
    pub fn is_active(&self) -> bool {
        self.keep_atoms.is_some() || self.to_fp8
    }

    fn apply(&self, s: &CsrSlab) -> CsrSlab {
        let mut out = match self.keep_atoms {
            Some(k) => s.retain_top(k),
            None => s.clone(),
        };
        if self.to_fp8 {
            out = out.to_precision(CoefPrecision::Fp8);
        }
        out
    }
}

/// Shared, thread-safe spill handle: one page file behind a poison-tolerant
/// mutex, cumulative counters, and session-snapshot storage in the same
/// directory. Cheaply clonable via `Arc` at the call sites.
pub struct SpillStore {
    file: Mutex<PageFile>,
    dir: PathBuf,
    cold: ColdTier,
    spilled_pages: AtomicU64,
    spilled_bytes: AtomicU64,
    faults: AtomicU64,
    faulted_bytes: AtomicU64,
}

impl SpillStore {
    /// Open (or create) a spill directory; pages live in `dir/pages.lxp`.
    pub fn open(dir: &Path) -> Result<SpillStore, StoreError> {
        fs::create_dir_all(dir)?;
        let file = PageFile::open(&dir.join("pages.lxp"))?;
        Ok(SpillStore {
            file: Mutex::new(file),
            dir: dir.to_path_buf(),
            cold: ColdTier::default(),
            spilled_pages: AtomicU64::new(0),
            spilled_bytes: AtomicU64::new(0),
            faults: AtomicU64::new(0),
            faulted_bytes: AtomicU64::new(0),
        })
    }

    pub fn with_cold_tier(mut self, cold: ColdTier) -> SpillStore {
        self.cold = cold;
        self
    }

    pub fn cold_tier(&self) -> ColdTier {
        self.cold
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn file(&self) -> MutexGuard<'_, PageFile> {
        // a panic while appending must not brick every other session
        self.file.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Spill one page, applying the cold tier if configured. Returns the
    /// page ref the caller stores in place of the resident page.
    pub fn spill(&self, k: &CsrSlab, v: &CsrSlab) -> Result<PageRef, StoreError> {
        let r = if self.cold.is_active() {
            let (ck, cv) = (self.cold.apply(k), self.cold.apply(v));
            self.file().append(&ck, &cv)?
        } else {
            self.file().append(k, v)?
        };
        self.spilled_pages.fetch_add(1, Ordering::Relaxed);
        self.spilled_bytes.fetch_add(r.len as u64, Ordering::Relaxed);
        Ok(r)
    }

    /// Fault one page back in, validating header + checksum + CSR shape.
    pub fn fault(&self, r: PageRef) -> Result<(CsrSlab, CsrSlab), StoreError> {
        let kv = self.file().read(r)?;
        self.faults.fetch_add(1, Ordering::Relaxed);
        self.faulted_bytes.fetch_add(r.len as u64, Ordering::Relaxed);
        Ok(kv)
    }

    /// Cumulative (pages spilled, bytes spilled, faults, bytes faulted).
    pub fn counters(&self) -> (u64, u64, u64, u64) {
        (
            self.spilled_pages.load(Ordering::Relaxed),
            self.spilled_bytes.load(Ordering::Relaxed),
            self.faults.load(Ordering::Relaxed),
            self.faulted_bytes.load(Ordering::Relaxed),
        )
    }

    /// Pages currently in the page file (append-only: never shrinks).
    pub fn pages_on_disk(&self) -> usize {
        self.file().pages()
    }

    fn snapshot_path(&self, name: &str) -> Result<PathBuf, StoreError> {
        if name.is_empty()
            || name.len() > 128
            || !name.bytes().all(|b| b.is_ascii_alphanumeric() || b == b'-' || b == b'_')
        {
            return Err(StoreError::Corrupt {
                offset: 0,
                what: format!("invalid session name {name:?} (want [A-Za-z0-9_-]{{1,128}})"),
            });
        }
        Ok(self.dir.join(format!("sess_{name}.lxs")))
    }

    /// Persist a session snapshot (atomically: temp file + rename). The
    /// blob is opaque to the store; pages it references stay in the shared
    /// page file.
    pub fn save_snapshot(&self, name: &str, blob: &[u8]) -> Result<(), StoreError> {
        let path = self.snapshot_path(name)?;
        let tmp = path.with_extension("tmp");
        let mut f = File::create(&tmp)?;
        f.write_all(blob)?;
        f.sync_all()?;
        fs::rename(&tmp, &path)?;
        Ok(())
    }

    /// Load a session snapshot; `Ok(None)` when no such session is saved.
    pub fn load_snapshot(&self, name: &str) -> Result<Option<Vec<u8>>, StoreError> {
        let path = self.snapshot_path(name)?;
        match fs::read(&path) {
            Ok(b) => Ok(Some(b)),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
            Err(e) => Err(e.into()),
        }
    }

    /// Remove a saved session snapshot (idempotent).
    pub fn drop_snapshot(&self, name: &str) -> Result<(), StoreError> {
        let path = self.snapshot_path(name)?;
        match fs::remove_file(&path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => Err(e.into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn slab_pair(rng: &mut Rng, rows: usize, prec: CoefPrecision) -> (CsrSlab, CsrSlab) {
        let mut k = CsrSlab::new(prec);
        let mut v = CsrSlab::new(prec);
        for _ in 0..rows {
            let nnz = 1 + rng.below(8);
            let idx: Vec<u16> = (0..nnz).map(|_| rng.below(512) as u16).collect();
            k.push_f32(&idx, &rng.normal_vec(nnz));
            let nnz = 1 + rng.below(8);
            let idx: Vec<u16> = (0..nnz).map(|_| rng.below(512) as u16).collect();
            v.push_f32(&idx, &rng.normal_vec(nnz));
        }
        (k, v)
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("lexico_store_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn assert_slab_eq(a: &CsrSlab, b: &CsrSlab) {
        assert_eq!(a.precision(), b.precision());
        if a.precision() == CoefMode::Sign {
            assert_eq!(a.sign_parts(), b.sign_parts());
        } else {
            assert_eq!(a.raw_parts(), b.raw_parts());
        }
    }

    #[test]
    fn encode_decode_round_trip_is_field_exact() {
        let mut rng = Rng::new(7);
        for prec in [CoefPrecision::Fp8, CoefPrecision::Fp16, CoefMode::Sign] {
            for rows in [0usize, 1, 5, 32] {
                let (k, v) = slab_pair(&mut rng, rows, prec);
                let buf = encode_page(&k, &v);
                let (k2, v2) = decode_page(&buf, 0).unwrap();
                assert_slab_eq(&k, &k2);
                assert_slab_eq(&v, &v2);
            }
        }
    }

    #[test]
    fn mixed_mode_pages_round_trip_per_side() {
        // K and V carry their coefficient mode independently in the header.
        let mut rng = Rng::new(70);
        let (k, _) = slab_pair(&mut rng, 9, CoefMode::Sign);
        let (_, v) = slab_pair(&mut rng, 9, CoefMode::Fp8);
        let buf = encode_page(&k, &v);
        let (k2, v2) = decode_page(&buf, 0).unwrap();
        assert_eq!(k2.precision(), CoefMode::Sign);
        assert_eq!(v2.precision(), CoefMode::Fp8);
        assert_slab_eq(&k, &k2);
        assert_slab_eq(&v, &v2);
    }

    #[test]
    fn decode_rejects_corruption() {
        let mut rng = Rng::new(8);
        let (k, v) = slab_pair(&mut rng, 4, CoefPrecision::Fp8);
        let good = encode_page(&k, &v);
        // bad magic
        let mut bad = good.clone();
        bad[0] ^= 0xff;
        assert!(matches!(decode_page(&bad, 0), Err(StoreError::Corrupt { .. })));
        // bad version (v1 pages predate the sign tier and must be rejected)
        let mut bad = good.clone();
        bad[4] = 0x01;
        let err = decode_page(&bad, 0).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
        // bad coefficient-mode byte
        let mut bad = good.clone();
        bad[6] = 9;
        assert!(matches!(decode_page(&bad, 0), Err(StoreError::Corrupt { .. })));
        // nonzero aux on a byte-coef side
        let mut bad = good.clone();
        bad[20] = 1;
        let err = decode_page(&bad, 0).unwrap_err();
        assert!(err.to_string().contains("aux"), "{err}");
        // flipped payload bit -> checksum mismatch
        let mut bad = good.clone();
        let n = bad.len();
        bad[n - 1] ^= 0x01;
        let err = decode_page(&bad, 0).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        // truncated
        assert!(decode_page(&good[..good.len() - 3], 0).is_err());
        assert!(decode_page(&good[..HEADER_LEN - 1], 0).is_err());
        // header row count inflated -> length mismatch (checked before payload walk)
        let mut bad = good.clone();
        bad[8] = bad[8].wrapping_add(1);
        assert!(decode_page(&bad, 0).is_err());
    }

    #[test]
    fn page_file_appends_reads_and_rebuilds_index() {
        let dir = tmpdir("pagefile");
        let path = dir.join("pages.lxp");
        let mut rng = Rng::new(9);
        let mut pages = Vec::new();
        let mut refs = Vec::new();
        {
            let mut pf = PageFile::open(&path).unwrap();
            for i in 0..6 {
                let prec = [CoefMode::Fp8, CoefMode::Fp16, CoefMode::Sign][i % 3];
                let (k, v) = slab_pair(&mut rng, 1 + i, prec);
                refs.push(pf.append(&k, &v).unwrap());
                pages.push((k, v));
            }
            assert_eq!(pf.pages(), 6);
            // read back out of order
            for (i, r) in refs.iter().enumerate().rev() {
                let (k, v) = pf.read(*r).unwrap();
                assert_slab_eq(&k, &pages[i].0);
                assert_slab_eq(&v, &pages[i].1);
            }
        }
        // reopen: index rebuilt by scan, refs unchanged
        let mut pf = PageFile::open(&path).unwrap();
        assert_eq!(pf.index(), &refs[..]);
        let (k, _) = pf.read(refs[3]).unwrap();
        assert_slab_eq(&k, &pages[3].0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn reopen_truncates_torn_tail() {
        let dir = tmpdir("torn");
        let path = dir.join("pages.lxp");
        let mut rng = Rng::new(10);
        let (k, v) = slab_pair(&mut rng, 3, CoefPrecision::Fp8);
        let good_end;
        {
            let mut pf = PageFile::open(&path).unwrap();
            pf.append(&k, &v).unwrap();
            good_end = pf.bytes();
            pf.append(&k, &v).unwrap();
        }
        // tear the second page mid-body
        let f = OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(good_end + HEADER_LEN as u64 + 2).unwrap();
        drop(f);
        let pf = PageFile::open(&path).unwrap();
        assert_eq!(pf.pages(), 1, "torn tail must be dropped");
        assert_eq!(pf.bytes(), good_end, "file truncated back to last good page");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_store_counts_and_round_trips() {
        let dir = tmpdir("spill");
        let store = SpillStore::open(&dir).unwrap();
        let mut rng = Rng::new(11);
        let (k, v) = slab_pair(&mut rng, 32, CoefPrecision::Fp16);
        let r = store.spill(&k, &v).unwrap();
        let (k2, v2) = store.fault(r).unwrap();
        assert_slab_eq(&k, &k2);
        assert_slab_eq(&v, &v2);
        let (sp, sb, fa, fb) = store.counters();
        assert_eq!((sp, fa), (1, 1));
        assert_eq!(sb, r.len as u64);
        assert_eq!(fb, r.len as u64);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn sign_pages_spill_and_fault_bitwise() {
        // The residency tier round-trips the sign tier's mode field and
        // bitmap exactly — fault(spill(p)) ≡ p holds for every mode.
        let dir = tmpdir("spill_sign");
        let store = SpillStore::open(&dir).unwrap();
        let mut rng = Rng::new(13);
        let (k, v) = slab_pair(&mut rng, 24, CoefMode::Sign);
        let r = store.spill(&k, &v).unwrap();
        let (k2, v2) = store.fault(r).unwrap();
        assert_eq!(k2.precision(), CoefMode::Sign);
        assert_slab_eq(&k, &k2);
        assert_slab_eq(&v, &v2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn cold_tier_shrinks_pages_and_is_lossy_but_valid() {
        let dir = tmpdir("cold");
        let store = SpillStore::open(&dir)
            .unwrap()
            .with_cold_tier(ColdTier { keep_atoms: Some(2), to_fp8: true });
        let mut rng = Rng::new(12);
        let (k, v) = slab_pair(&mut rng, 16, CoefPrecision::Fp16);
        let r = store.spill(&k, &v).unwrap();
        let (ck, cv) = store.fault(r).unwrap();
        assert_eq!(ck.rows(), k.rows());
        assert_eq!(cv.rows(), v.rows());
        assert_eq!(ck.precision(), CoefPrecision::Fp8);
        assert!(ck.bytes() + cv.bytes() < k.bytes() + v.bytes());
        for row in 0..ck.rows() {
            assert!(ck.row(row).0.len() <= 2);
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshots_save_load_drop() {
        let dir = tmpdir("snap");
        let store = SpillStore::open(&dir).unwrap();
        assert!(store.load_snapshot("alice").unwrap().is_none());
        store.save_snapshot("alice", b"state-bytes").unwrap();
        assert_eq!(store.load_snapshot("alice").unwrap().unwrap(), b"state-bytes");
        store.save_snapshot("alice", b"newer").unwrap(); // overwrite
        assert_eq!(store.load_snapshot("alice").unwrap().unwrap(), b"newer");
        store.drop_snapshot("alice").unwrap();
        assert!(store.load_snapshot("alice").unwrap().is_none());
        store.drop_snapshot("alice").unwrap(); // idempotent
        // bad names rejected
        assert!(store.save_snapshot("../escape", b"x").is_err());
        assert!(store.load_snapshot("").is_err());
        let _ = fs::remove_dir_all(&dir);
    }
}
