//! Synthetic task suite + tokenizer — the Rust side of the data contract.
//!
//! Mirrors `python/compile/data.py` exactly (vocabulary, task formats);
//! a cross-language test asserts `VOCAB_CHARS == artifacts/vocab.txt`.
//! Evaluation uses different PRNG seeds than training, so eval data is
//! held out by construction.

use crate::util::rng::Rng;

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
const SPECIALS: usize = 3;

/// Must match `data.py::VOCAB_CHARS` byte for byte.
pub const VOCAB_CHARS: &str = "\n abcdefghijklmnopqrstuvwxyz0123456789=+-*;:,.?#()<>[]";

pub fn vocab_size() -> usize {
    SPECIALS + VOCAB_CHARS.len()
}

/// Token id of a character, or `None` when out of vocabulary.
pub fn try_char_id(c: char) -> Option<u32> {
    VOCAB_CHARS.find(c).map(|i| (SPECIALS + i) as u32)
}

/// Token id of a character (panics on out-of-vocabulary — a format bug in
/// *generated* text; external input must go through [`try_encode`]).
pub fn char_id(c: char) -> u32 {
    try_char_id(c).unwrap_or_else(|| panic!("OOV char {c:?}"))
}

pub fn newline_id() -> u32 {
    char_id('\n')
}

/// Encode text that is known to be in-vocabulary (task generators,
/// round-trips of decoded output). Panics on OOV — see [`try_encode`] for
/// the fallible path that server requests must take.
pub fn encode(text: &str) -> Vec<u32> {
    text.chars().map(char_id).collect()
}

/// Fallible encoding for untrusted input (server requests): reports the
/// first out-of-vocabulary character and its position instead of
/// panicking, so a malformed request becomes an error reply rather than a
/// crashed batcher thread.
pub fn try_encode(text: &str) -> Result<Vec<u32>, String> {
    text.chars()
        .enumerate()
        .map(|(i, c)| {
            try_char_id(c).ok_or_else(|| format!("unsupported character {c:?} at position {i}"))
        })
        .collect()
}

/// Encode, silently dropping out-of-vocabulary characters (server inputs).
pub fn encode_lossy(text: &str) -> Vec<u32> {
    text.chars()
        .filter_map(|c| VOCAB_CHARS.find(c).map(|i| (SPECIALS + i) as u32))
        .collect()
}

pub fn decode(ids: &[u32]) -> String {
    ids.iter()
        .filter_map(|&i| VOCAB_CHARS.chars().nth((i as usize).checked_sub(SPECIALS)?))
        .collect()
}

// ---------------------------------------------------------------------------
// Task instances and scoring
// ---------------------------------------------------------------------------

/// A generated task instance: prompt text and the expected continuation.
#[derive(Clone, Debug)]
pub struct Instance {
    pub prompt: String,
    pub answer: String,
}

/// How a task is scored.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    /// Exact match of the generated answer (GSM8K-style accuracy).
    ExactMatch,
    /// Normalized edit similarity (LCC/RepoBench-style).
    EditSim,
    /// Perplexity (reported as exp(mean NLL); lower better).
    Perplexity,
}

/// Task family (see DESIGN.md §1 for the paper-task mapping).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// GSM8K substitute: few-shot multi-step arithmetic chains.
    Arith,
    /// MMLU-Pro Engineering substitute: deeper chains.
    ArithHard,
    /// TREC/TriviaQA-style retrieval: key/value recall over long context.
    Needle,
    /// LCC/RepoBench-style: verbatim long-range copy.
    Copy,
    /// MMLU-Pro Law substitute: sorting.
    Sort,
    /// Summarization-proxy: LM perplexity on held-out prose.
    Lm,
}

pub const ALL_TASKS: [Task; 6] =
    [Task::Arith, Task::ArithHard, Task::Needle, Task::Copy, Task::Sort, Task::Lm];

impl Task {
    pub fn name(&self) -> &'static str {
        match self {
            Task::Arith => "arith",
            Task::ArithHard => "arith-hard",
            Task::Needle => "needle",
            Task::Copy => "copy",
            Task::Sort => "sort",
            Task::Lm => "lm",
        }
    }

    pub fn from_name(s: &str) -> Option<Task> {
        ALL_TASKS.iter().copied().find(|t| t.name() == s)
    }

    pub fn metric(&self) -> Metric {
        match self {
            Task::Copy => Metric::EditSim,
            Task::Lm => Metric::Perplexity,
            _ => Metric::ExactMatch,
        }
    }

    /// Generate one instance. `scale` ∈ [0,1] stretches the context length
    /// (long-context sweeps use scale=1).
    pub fn gen(&self, rng: &mut Rng, scale: f64) -> Instance {
        match self {
            Task::Arith => {
                let steps = 3 + rng.below(2);
                gen_arith_prompt(rng, steps, 4)
            }
            Task::ArithHard => {
                let steps = 5 + rng.below(3);
                gen_arith_prompt(rng, steps, 4)
            }
            Task::Needle => {
                // cap so instances fit inside the training window (256
                // tokens) — the model never saw longer intact examples
                let n = (8.0 + 12.0 * scale) as usize + rng.below(8);
                gen_needle(rng, n)
            }
            Task::Copy => {
                let n = (16.0 + 44.0 * scale) as usize + rng.below(8);
                gen_copy(rng, n)
            }
            Task::Sort => {
                let n = 5 + rng.below(4);
                gen_sort(rng, n)
            }
            Task::Lm => Instance { prompt: gen_lm_text(rng, 220), answer: String::new() },
        }
    }
}

/// Score one generated answer against the expected one.
pub fn score(metric: Metric, generated: &str, expected: &str) -> f64 {
    match metric {
        Metric::ExactMatch => (generated.trim_end_matches('\n') == expected) as u8 as f64,
        Metric::EditSim => edit_similarity(generated.trim_end_matches('\n'), expected),
        Metric::Perplexity => unreachable!("perplexity is computed from NLL, not text"),
    }
}

/// 1 − levenshtein/len (the LongBench "edit similarity" metric).
pub fn edit_similarity(a: &str, b: &str) -> f64 {
    let (a, b): (Vec<char>, Vec<char>) = (a.chars().collect(), b.chars().collect());
    let (la, lb) = (a.len(), b.len());
    if la == 0 && lb == 0 {
        return 1.0;
    }
    let mut prev: Vec<usize> = (0..=lb).collect();
    let mut cur = vec![0usize; lb + 1];
    for i in 1..=la {
        cur[0] = i;
        for j in 1..=lb {
            let sub = prev[j - 1] + (a[i - 1] != b[j - 1]) as usize;
            cur[j] = sub.min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    1.0 - prev[lb] as f64 / la.max(lb) as f64
}

// ---------------------------------------------------------------------------
// Generators (formats identical to data.py)
// ---------------------------------------------------------------------------

const VARS: &[u8] = b"abcdefghij";

/// One arithmetic chain: (`a=3;b=a+4;...;x?`, answer). Values mod 100.
pub fn gen_arith_example(rng: &mut Rng, n_steps: usize) -> Instance {
    let mut vals = [0i64; 10];
    let mut parts: Vec<String> = Vec::with_capacity(n_steps);
    for i in 0..n_steps {
        let var = VARS[i] as char;
        let v = if i == 0 {
            let v = 1 + rng.below(9) as i64;
            parts.push(format!("{var}={v}"));
            v
        } else {
            let src = rng.below(i);
            let op = *rng.choice(b"+-*") as char;
            let operand = 1 + rng.below(9) as i64;
            let sv = vals[src];
            let v = match op {
                '+' => (sv + operand as i64).rem_euclid(100),
                '-' => (sv - operand as i64).rem_euclid(100),
                _ => (sv * operand as i64).rem_euclid(100),
            };
            parts.push(format!("{var}={}{op}{operand}", VARS[src] as char));
            v
        };
        vals[i] = v;
    }
    let q = VARS[n_steps - 1] as char;
    Instance {
        prompt: format!("{};{q}?", parts.join(";")),
        answer: vals[n_steps - 1].to_string(),
    }
}

/// Few-shot arithmetic prompt: `n_shots` solved chains then a query.
pub fn gen_arith_prompt(rng: &mut Rng, n_steps: usize, n_shots: usize) -> Instance {
    let mut lines: Vec<String> = Vec::with_capacity(n_shots + 1);
    for _ in 0..n_shots {
        let ex = gen_arith_example(rng, n_steps);
        lines.push(format!("{}{}", ex.prompt, ex.answer));
    }
    let q = gen_arith_example(rng, n_steps);
    lines.push(q.prompt);
    Instance { prompt: lines.join("\n"), answer: q.answer }
}

/// Needle: `k17=v42;...;k17?` → `v42`.
pub fn gen_needle(rng: &mut Rng, n_pairs: usize) -> Instance {
    let n_pairs = n_pairs.min(100);
    let mut keys: Vec<usize> = (0..100).collect();
    rng.shuffle(&mut keys);
    let pairs: Vec<(usize, usize)> =
        keys[..n_pairs].iter().map(|&k| (k, rng.below(100))).collect();
    let ctx: Vec<String> = pairs.iter().map(|(k, v)| format!("k{k:02}=v{v:02}")).collect();
    let (qk, qv) = pairs[rng.below(n_pairs)];
    Instance {
        prompt: format!("{};k{qk:02}?", ctx.join(";")),
        answer: format!("v{qv:02}"),
    }
}

/// Copy: `<letters>#` → the same letters.
pub fn gen_copy(rng: &mut Rng, n_chars: usize) -> Instance {
    let s: String = (0..n_chars)
        .map(|_| (b'a' + rng.below(26) as u8) as char)
        .collect();
    Instance { prompt: format!("{s}#"), answer: s }
}

/// Sort: `7,3,9,1>` → `1,3,7,9`.
pub fn gen_sort(rng: &mut Rng, n_digits: usize) -> Instance {
    let ds: Vec<usize> = (0..n_digits).map(|_| rng.below(10)).collect();
    let mut sorted = ds.clone();
    sorted.sort_unstable();
    let fmt = |v: &[usize]| v.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",");
    Instance { prompt: format!("{}>", fmt(&ds)), answer: fmt(&sorted) }
}

// Markov prose (mirrors data.py's word lists and transition table).
const WORDS: &str = "the a one this that red blue green small large old new dark cold \
fox dog cat bird fish tree river stone house door city road cloud \
runs jumps sleeps sings falls rises moves turns stands waits \
over under near beside into from with without through around \
quickly slowly quietly loudly gently always never often soon \
and but then while because";

fn word_kinds() -> Vec<Vec<&'static str>> {
    let words: Vec<&str> = WORDS.split_whitespace().collect();
    let bounds = [0usize, 14, 28, 38, 48, 58, words.len()];
    (0..6).map(|k| words[bounds[k]..bounds[k + 1]].to_vec()).collect()
}

const NEXT: [[usize; 4]; 6] = [
    [0, 1, 1, 1],
    [2, 2, 2, 3],
    [3, 3, 4, 5],
    [0, 0, 1, 1],
    [5, 0, 2, 3],
    [0, 0, 1, 4],
];

/// Markov-chain prose of roughly `n_chars` characters.
pub fn gen_lm_text(rng: &mut Rng, n_chars: usize) -> String {
    let by_kind = word_kinds();
    let mut out = String::new();
    while out.len() < n_chars {
        let mut kind = 0usize;
        let sent_len = 5 + rng.below(9);
        let mut words = Vec::with_capacity(sent_len);
        for _ in 0..sent_len {
            words.push(*rng.choice(&by_kind[kind]));
            kind = *rng.choice(&NEXT[kind]);
        }
        out.push_str(&words.join(" "));
        out.push_str(". ");
    }
    out.truncate(n_chars);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_encoding() {
        let s = "a=3;b=a+4;b?7\nk01=v02";
        assert_eq!(decode(&encode(s)), s);
        assert_eq!(vocab_size(), 57);
    }

    #[test]
    fn try_encode_reports_oov_instead_of_panicking() {
        assert_eq!(try_encode("a=3;a?").unwrap(), encode("a=3;a?"));
        let err = try_encode("ab\u{e9}cd").unwrap_err();
        assert!(err.contains('\u{e9}') && err.contains("position 2"), "{err}");
        assert!(try_encode("UPPER").is_err(), "uppercase is out of vocab");
        assert_eq!(try_char_id('a'), Some(char_id('a')));
        assert_eq!(try_char_id('é'), None);
    }

    #[test]
    fn arith_answers_are_correct() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let ex = gen_arith_example(&mut rng, 4);
            // re-evaluate the chain with a tiny interpreter
            let mut vals: std::collections::HashMap<String, i64> = std::collections::HashMap::new();
            let (chain, q) = ex.prompt.rsplit_once(';').unwrap();
            for stmt in chain.split(';') {
                let (var, expr) = stmt.split_once('=').unwrap();
                let v: i64 = if let Some(pos) = expr.find(['+', '-', '*']) {
                    let (src, rest) = expr.split_at(pos);
                    let op = rest.chars().next().unwrap();
                    let operand: i64 = rest[1..].parse().unwrap();
                    let sv = vals[src];
                    match op {
                        '+' => (sv + operand as i64).rem_euclid(100),
                        '-' => (sv - operand as i64).rem_euclid(100),
                        _ => (sv * operand as i64).rem_euclid(100),
                    }
                } else {
                    expr.parse().unwrap()
                };
                vals.insert(var.to_string(), v);
            }
            let qvar = q.trim_end_matches('?');
            assert_eq!(vals[qvar].to_string(), ex.answer, "{}", ex.prompt);
        }
    }

    #[test]
    fn needle_answer_is_in_context() {
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let ex = gen_needle(&mut rng, 12);
            let q = ex.prompt.rsplit(';').next().unwrap().trim_end_matches('?');
            assert!(ex.prompt.contains(&format!("{q}={}", ex.answer)), "{}", ex.prompt);
        }
    }

    #[test]
    fn sort_is_sorted() {
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let ex = gen_sort(&mut rng, 6);
            let mut ds: Vec<i32> =
                ex.answer.split(',').map(|d| d.parse().unwrap()).collect();
            let orig = ds.clone();
            ds.sort_unstable();
            assert_eq!(ds, orig);
        }
    }

    #[test]
    fn edit_similarity_properties() {
        assert_eq!(edit_similarity("abc", "abc"), 1.0);
        assert_eq!(edit_similarity("", ""), 1.0);
        assert!((edit_similarity("abcd", "abce") - 0.75).abs() < 1e-9);
        assert_eq!(edit_similarity("abc", ""), 0.0);
    }

    #[test]
    fn all_tasks_generate_in_vocab() {
        let mut rng = Rng::new(4);
        for task in ALL_TASKS {
            for _ in 0..5 {
                let ex = task.gen(&mut rng, 1.0);
                let _ = encode(&ex.prompt); // panics on OOV
                let _ = encode(&ex.answer);
                assert!(!ex.prompt.is_empty());
            }
        }
    }

    #[test]
    fn lm_text_statistics() {
        let mut rng = Rng::new(5);
        let text = gen_lm_text(&mut rng, 500);
        assert!(text.len() == 500);
        assert!(text.contains(". "));
        let _ = encode(&text);
    }
}
