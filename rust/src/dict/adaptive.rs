//! Adaptive dictionary learning at generation time (paper §4.2.4).
//!
//! Starting from the pretrained universal dictionary, whenever OMP fails to
//! meet the relative-error threshold δ for a vector, that vector is
//! normalized and *added as a new atom*; the vector is then stored with
//! sparsity 1 (index = the new atom, coefficient = its ℓ2 norm). Added
//! atoms are session-private and therefore charged to the KV size
//! (FP16 per element), exactly as the paper accounts for them.

use crate::dict::Dictionary;
use crate::omp::{omp_encode, rel_error, OmpWorkspace, SparseCode};
use crate::tensor::norm2;

/// A universal dictionary plus session-local adaptive atoms.
///
/// `Clone` deep-copies the atom storage: adaptive growth is session
/// state, so a forked session keeps its own overlay from the fork point.
#[derive(Clone)]
pub struct AdaptiveDict {
    /// base + added atoms, atom-major (base occupies the prefix)
    atoms: Vec<f32>,
    pub m: usize,
    pub n_base: usize,
    pub n_extra: usize,
    pub max_extra: usize,
    /// relative reconstruction error threshold δ
    pub delta: f32,
}

impl AdaptiveDict {
    pub fn new(base: &Dictionary, max_extra: usize, delta: f32) -> Self {
        let mut atoms = base.atoms.clone();
        atoms.reserve(max_extra * base.m);
        AdaptiveDict {
            atoms,
            m: base.m,
            n_base: base.n,
            n_extra: 0,
            max_extra,
            delta,
        }
    }

    pub fn n_atoms(&self) -> usize {
        self.n_base + self.n_extra
    }

    pub fn atoms(&self) -> &[f32] {
        &self.atoms
    }

    /// Encode `x`; if the δ target is unmet at sparsity `s_max` and there is
    /// room, add x/‖x‖ as a new atom and encode as (new_atom, ‖x‖) with s=1.
    /// Returns (code, grew_dictionary).
    pub fn encode(&mut self, x: &[f32], s_max: usize, ws: &mut OmpWorkspace) -> (SparseCode, bool) {
        let n = self.n_atoms();
        let code = omp_encode(&self.atoms, n, self.m, x, s_max, self.delta, ws);
        let err = rel_error(&self.atoms, self.m, x, &code);
        if err <= self.delta || self.n_extra >= self.max_extra {
            return (code, false);
        }
        let nrm = norm2(x);
        if nrm < 1e-12 {
            return (code, false);
        }
        let new_id = n;
        self.atoms.extend(x.iter().map(|&v| v / nrm));
        self.n_extra += 1;
        (
            SparseCode { idx: vec![new_id as u16], val: vec![nrm] },
            true,
        )
    }

    /// Bytes charged to the KV cache for the added atoms (FP16 elements).
    pub fn extra_bytes(&self) -> usize {
        self.n_extra * self.m * 2
    }

    /// The session-local overlay atoms (atom-major, `n_extra × m`) — the
    /// slice a dictionary-refresh pass folds back into the universal
    /// dictionary via [`Dictionary::refreshed`].
    pub fn extra_atoms(&self) -> &[f32] {
        &self.atoms[self.n_base * self.m..]
    }

    /// Absorb the overlay into the base after a refresh: the base
    /// dictionary now owns every atom this overlay holds (same values,
    /// same indices — `atoms` is already contiguous base+extra), so the
    /// extra count resets and the full `max_extra` headroom reopens.
    pub fn rebase(&mut self) {
        self.n_base += self.n_extra;
        self.n_extra = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn grows_on_hard_vectors_then_reuses_them() {
        let m = 16;
        let base = Dictionary::random(m, 32, 5);
        let mut ad = AdaptiveDict::new(&base, 8, 0.05);
        let mut ws = OmpWorkspace::new(64, m, 4);
        let mut rng = Rng::new(9);
        let x = rng.normal_vec(m); // random vector: tiny dict can't hit δ=0.05
        let (code, grew) = ad.encode(&x, 2, &mut ws);
        assert!(grew, "should add an atom");
        assert_eq!(code.nnz(), 1);
        assert_eq!(code.idx[0] as usize, 32);
        assert!((code.val[0] - norm2(&x)).abs() < 1e-5);
        // re-encoding the same vector now succeeds without growth
        let (code2, grew2) = ad.encode(&x, 2, &mut ws);
        assert!(!grew2);
        let err = rel_error(ad.atoms(), m, &x, &code2);
        assert!(err < 0.05, "err {err}");
        assert_eq!(ad.extra_bytes(), 16 * 2);
    }

    #[test]
    fn respects_max_extra() {
        let m = 8;
        let base = Dictionary::random(m, 16, 1);
        let mut ad = AdaptiveDict::new(&base, 2, 0.01);
        let mut ws = OmpWorkspace::new(64, m, 2);
        let mut rng = Rng::new(3);
        let mut grown = 0;
        for _ in 0..10 {
            let x = rng.normal_vec(m);
            let (_, grew) = ad.encode(&x, 1, &mut ws);
            grown += grew as usize;
        }
        assert_eq!(grown, 2);
        assert_eq!(ad.n_extra, 2);
    }

    #[test]
    fn rebase_folds_overlay_and_reopens_headroom() {
        let m = 8;
        let base = Dictionary::random(m, 16, 1);
        let mut ad = AdaptiveDict::new(&base, 1, 0.01);
        let mut ws = OmpWorkspace::new(64, m, 2);
        let mut rng = Rng::new(4);
        let x = rng.normal_vec(m);
        let (code, grew) = ad.encode(&x, 1, &mut ws);
        assert!(grew);
        let overlay = ad.extra_atoms().to_vec();
        assert_eq!(overlay.len(), m);

        // fold into a refreshed base: same atoms, same indices
        let refreshed = base.refreshed(&overlay);
        ad.rebase();
        assert_eq!(ad.n_base, 17);
        assert_eq!(ad.n_extra, 0);
        assert_eq!(ad.extra_bytes(), 0);
        assert_eq!(ad.atoms(), &refreshed.atoms[..]);
        assert_eq!(ad.extra_atoms(), &[] as &[f32]);
        // the sparse code encoded pre-refresh decodes against the refreshed
        // base: index 16 is the folded atom
        assert_eq!(code.idx[0], 16);
        assert_eq!(refreshed.atom(16), &overlay[..]);

        // headroom reopened: the next hard vector can grow again
        let y = rng.normal_vec(m);
        let (_, grew2) = ad.encode(&y, 1, &mut ws);
        assert!(grew2, "rebase must reopen max_extra headroom");
        assert_eq!(ad.n_extra, 1);
    }
}
