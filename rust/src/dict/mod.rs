//! Universal dictionaries: loading (LXDC), random baselines, SAE baseline
//! (LXSA), native training, and runtime-adaptive extension.

pub mod adaptive;
pub mod train;

use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;
use std::sync::{Arc, OnceLock};

use crate::exec::ExecPool;
use crate::tensor::{norm2, par_syrk};

/// One dictionary: `n` unit-norm atoms of dimension `m`, **atom-major**
/// storage (`atoms[a*m..(a+1)*m]` is atom `a`) — the layout the OMP
/// correlation loop streams.
#[derive(Clone, Debug)]
pub struct Dictionary {
    pub m: usize,
    pub n: usize,
    pub atoms: Vec<f32>,
    /// Lazily realized Gram matrix G = D·Dᵀ (`[n, n]`, full symmetric
    /// storage) for the precomputed-Gram OMP tier — computed once per
    /// dictionary **generation**, then shared by every session/layer/head
    /// (cloning a `Dictionary` clones the `Arc`, not the 4·n² bytes). The
    /// cache is never invalidated in place: any atom change must rotate to
    /// a new generation via [`Dictionary::refreshed`], which starts with a
    /// fresh, unrealized `OnceLock`. Realize only after the atoms are
    /// final for the current generation.
    gram: OnceLock<Arc<Vec<f32>>>,
    /// Monotone refresh counter: 0 for every freshly constructed
    /// dictionary, bumped by [`Dictionary::refreshed`]. Lets callers
    /// assert they are not holding a Gram from a superseded atom set.
    generation: u64,
}

impl Dictionary {
    pub fn new(m: usize, n: usize, atoms: Vec<f32>) -> Self {
        debug_assert_eq!(atoms.len(), n * m);
        Dictionary { m, n, atoms, gram: OnceLock::new(), generation: 0 }
    }

    /// From column-major [m, N] layout (the LXDC / JAX convention).
    pub fn from_m_by_n(m: usize, n: usize, data: &[f32]) -> Self {
        let mut atoms = vec![0.0; n * m];
        for a in 0..n {
            for i in 0..m {
                atoms[a * m + i] = data[i * n + a];
            }
        }
        Dictionary { m, n, atoms, gram: OnceLock::new(), generation: 0 }
    }

    /// Random unit-norm dictionary (Table 1 baseline).
    pub fn random(m: usize, n: usize, seed: u64) -> Self {
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut atoms = rng.normal_vec(n * m);
        for a in atoms.chunks_mut(m) {
            let nrm = norm2(a).max(1e-12);
            a.iter_mut().for_each(|x| *x /= nrm);
        }
        Dictionary { m, n, atoms, gram: OnceLock::new(), generation: 0 }
    }

    /// Refresh generation of this dictionary (0 until the first
    /// [`Dictionary::refreshed`]).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// The explicit Gram-invalidation path: build the next generation of
    /// this dictionary with `extra` atoms (length a multiple of `m`,
    /// atom-major) appended after the existing `n`. Existing atom indices
    /// are preserved, so sparse codes encoded against this dictionary stay
    /// decodable against the refreshed one. The returned dictionary has a
    /// fresh, unrealized Gram cache and `generation + 1` — the stale G of
    /// the old generation can never be observed through the new value.
    pub fn refreshed(&self, extra: &[f32]) -> Dictionary {
        assert_eq!(extra.len() % self.m, 0, "extra atoms must be atom-major [k, m]");
        let mut atoms = Vec::with_capacity(self.atoms.len() + extra.len());
        atoms.extend_from_slice(&self.atoms);
        atoms.extend_from_slice(extra);
        Dictionary {
            m: self.m,
            n: self.n + extra.len() / self.m,
            atoms,
            gram: OnceLock::new(),
            generation: self.generation + 1,
        }
    }

    /// The dictionary's Gram matrix G = D·Dᵀ, realized on first request via
    /// [`par_syrk`] on `pool` and cached for the life of the instance —
    /// every later caller (any thread) gets the same `Arc`. Costs 4·n²
    /// bytes (~64 MB at n = 4096); see [`Dictionary::gram_bytes`] for the
    /// memory-reporting side.
    pub fn gram(&self, pool: &ExecPool) -> Arc<Vec<f32>> {
        self.gram
            .get_or_init(|| {
                let mut g = vec![0.0f32; self.n * self.n];
                par_syrk(pool, &mut g, &self.atoms, self.n, self.m);
                Arc::new(g)
            })
            .clone()
    }

    /// Bytes held by the realized Gram cache (0 until [`Dictionary::gram`]
    /// first runs).
    pub fn gram_bytes(&self) -> usize {
        self.gram.get().map(|g| g.len() * 4).unwrap_or(0)
    }

    pub fn atom(&self, a: usize) -> &[f32] {
        &self.atoms[a * self.m..(a + 1) * self.m]
    }

    /// Re-normalize all atoms to unit norm (defensive, applied on load).
    pub fn renormalize(&mut self) {
        for a in self.atoms.chunks_mut(self.m) {
            let nrm = norm2(a).max(1e-12);
            a.iter_mut().for_each(|x| *x /= nrm);
        }
    }

    /// Storage bytes (FP16 accounting — dictionaries are shared, constant
    /// memory; reported for DESIGN.md context, not charged to KV size).
    pub fn bytes_fp16(&self) -> usize {
        self.n * self.m * 2
    }
}

/// Per-layer K and V dictionaries for one model (paper §3.3).
#[derive(Clone, Debug)]
pub struct DictionarySet {
    pub keys: Vec<Dictionary>,
    pub values: Vec<Dictionary>,
}

impl DictionarySet {
    /// Load an LXDC file (see `aot.py::save_dict_bin`).
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"LXDC" {
            bail!("{}: bad magic", path.display());
        }
        let mut hdr = [0u8; 16];
        f.read_exact(&mut hdr)?;
        let u = |i: usize| u32::from_le_bytes(hdr[i * 4..i * 4 + 4].try_into().unwrap()) as usize;
        let (ver, ll, m, n) = (u(0), u(1), u(2), u(3));
        if ver != 1 {
            bail!("unsupported LXDC version {ver}");
        }
        let read_layer = |f: &mut dyn Read| -> Result<Dictionary> {
            let mut bytes = vec![0u8; m * n * 4];
            f.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let mut d = Dictionary::from_m_by_n(m, n, &data);
            d.renormalize();
            Ok(d)
        };
        let mut keys = Vec::with_capacity(ll);
        for _ in 0..ll {
            keys.push(read_layer(&mut f)?);
        }
        let mut values = Vec::with_capacity(ll);
        for _ in 0..ll {
            values.push(read_layer(&mut f)?);
        }
        Ok(DictionarySet { keys, values })
    }

    /// Random-dictionary set with the same shape (Table 1 baseline).
    pub fn random_like(&self, seed: u64) -> Self {
        DictionarySet {
            keys: self
                .keys
                .iter()
                .enumerate()
                .map(|(i, d)| Dictionary::random(d.m, d.n, seed ^ (i as u64)))
                .collect(),
            values: self
                .values
                .iter()
                .enumerate()
                .map(|(i, d)| Dictionary::random(d.m, d.n, seed ^ 0x8000 ^ (i as u64)))
                .collect(),
        }
    }

    /// Total bytes held by realized Gram caches across every layer's K and
    /// V dictionaries — the `gram_bytes` metrics gauge the server reports
    /// (0 until some cache opts into the gram tier and touches a layer).
    pub fn gram_bytes(&self) -> usize {
        self.keys
            .iter()
            .chain(self.values.iter())
            .map(|d| d.gram_bytes())
            .sum()
    }
}

/// Sparse-autoencoder baseline weights (LXSA file; Table 1).
#[derive(Clone, Debug)]
pub struct SaePair {
    pub m: usize,
    pub n: usize,
    /// encoders/decoders stored [m, N] row-major as in the file
    pub enc_k: Vec<f32>,
    pub dec_k: Vec<f32>,
    pub enc_v: Vec<f32>,
    pub dec_v: Vec<f32>,
}

impl SaePair {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != b"LXSA" {
            bail!("{}: bad magic", path.display());
        }
        let mut hdr = [0u8; 12];
        f.read_exact(&mut hdr)?;
        let u = |i: usize| u32::from_le_bytes(hdr[i * 4..i * 4 + 4].try_into().unwrap()) as usize;
        let (ver, m, n) = (u(0), u(1), u(2));
        if ver != 1 {
            bail!("unsupported LXSA version {ver}");
        }
        let mut read_mat = || -> Result<Vec<f32>> {
            let mut bytes = vec![0u8; m * n * 4];
            f.read_exact(&mut bytes)?;
            Ok(bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect())
        };
        Ok(SaePair {
            m,
            n,
            enc_k: read_mat()?,
            dec_k: read_mat()?,
            enc_v: read_mat()?,
            dec_v: read_mat()?,
        })
    }

    /// Encode with hard top-k, decode, return relative ℓ2 error of `x`.
    pub fn rel_error(&self, x: &[f32], s: usize, use_keys: bool) -> f32 {
        let (enc, dec) = if use_keys {
            (&self.enc_k, &self.dec_k)
        } else {
            (&self.enc_v, &self.dec_v)
        };
        // z = x · enc  ([m]·[m,N] → [N])
        let mut z = vec![0.0f32; self.n];
        for i in 0..self.m {
            let xi = x[i];
            if xi != 0.0 {
                crate::tensor::axpy(&mut z, xi, &enc[i * self.n..(i + 1) * self.n]);
            }
        }
        // hard top-s by |z|
        let mut order: Vec<usize> = (0..self.n).collect();
        order.sort_by(|&a, &b| z[b].abs().partial_cmp(&z[a].abs()).unwrap());
        let keep = &order[..s.min(self.n)];
        // recon = Σ z_j dec[:, j]
        let mut recon = vec![0.0f32; self.m];
        for &j in keep {
            for i in 0..self.m {
                recon[i] += z[j] * dec[i * self.n + j];
            }
        }
        let mut err = 0.0f32;
        for i in 0..self.m {
            let d = x[i] - recon[i];
            err += d * d;
        }
        err.sqrt() / norm2(x).max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transpose_layout() {
        // m=2, n=3 column-major input [m,N]: row0 = atoms' dim0, row1 = dim1
        let data = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let d = Dictionary::from_m_by_n(2, 3, &data);
        assert_eq!(d.atom(0), &[1.0, 4.0]);
        assert_eq!(d.atom(1), &[2.0, 5.0]);
        assert_eq!(d.atom(2), &[3.0, 6.0]);
    }

    #[test]
    fn random_is_unit_norm() {
        let d = Dictionary::random(16, 64, 7);
        for a in 0..d.n {
            let nrm = norm2(d.atom(a));
            assert!((nrm - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn gram_is_lazy_shared_and_counted() {
        let d = Dictionary::random(8, 32, 3);
        assert_eq!(d.gram_bytes(), 0, "gram must not exist before first use");
        let pool = ExecPool::new(2);
        let g1 = d.gram(&pool);
        let g2 = d.gram(&pool);
        assert!(Arc::ptr_eq(&g1, &g2), "second request must share the Arc");
        assert_eq!(d.gram_bytes(), 32 * 32 * 4);
        // clones share the realized cache (Arc clone, not a recompute)
        let c = d.clone();
        assert!(Arc::ptr_eq(&c.gram(&pool), &g1));
        // unit-norm atoms: the diagonal is each atom's squared norm
        for i in 0..32 {
            assert!((g1[i * 32 + i] - 1.0).abs() < 1e-5, "diag[{i}]");
        }
        // set-level accounting sums only realized caches
        let set = DictionarySet { keys: vec![d], values: vec![Dictionary::random(8, 16, 4)] };
        assert_eq!(set.gram_bytes(), 32 * 32 * 4);
    }

    #[test]
    fn refresh_rotates_generation_and_never_serves_stale_gram() {
        let d = Dictionary::random(8, 32, 5);
        assert_eq!(d.generation(), 0);
        let pool = ExecPool::new(2);
        let g_old = d.gram(&pool); // realize generation 0's Gram
        assert_eq!(d.gram_bytes(), 32 * 32 * 4);

        // refresh with two extra atoms: new generation, larger n, old
        // indices preserved, and an UNREALIZED Gram (explicit invalidation)
        let mut rng = crate::util::rng::Rng::new(6);
        let mut extra = rng.normal_vec(2 * 8);
        for a in extra.chunks_mut(8) {
            let nrm = norm2(a).max(1e-12);
            a.iter_mut().for_each(|x| *x /= nrm);
        }
        let d2 = d.refreshed(&extra);
        assert_eq!(d2.generation(), 1);
        assert_eq!(d2.n, 34);
        assert_eq!(d2.atom(7), d.atom(7), "base atom indices must be preserved");
        assert_eq!(d2.atom(32), &extra[..8]);
        assert_eq!(d2.gram_bytes(), 0, "refresh must drop the realized Gram");

        let g_new = d2.gram(&pool);
        assert_eq!(g_new.len(), 34 * 34, "new Gram covers the refreshed atom set");
        assert!(!Arc::ptr_eq(&g_old, &g_new));
        // chained refreshes keep counting up
        assert_eq!(d2.refreshed(&[]).generation(), 2);
    }
}
