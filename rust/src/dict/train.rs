//! Native dictionary trainer (paper §3.3 recipe, in Rust).
//!
//! The primary dictionaries ship from the JAX trainer (`dictlearn.py`); this
//! native implementation exists so the system is self-contained (the
//! `lexico train-dict` subcommand, the Table 1 cross-check, and the
//! `adaptive_dict` example) and follows the same recipe: OMP encode with the
//! current dictionary, ℓ2 reconstruction loss, Adam on the atoms with
//! gradient components parallel to each atom removed, unit-norm projection.

use crate::dict::Dictionary;
use crate::omp::{omp_encode, OmpWorkspace};
use crate::tensor::{axpy, dot, norm2};
use crate::util::rng::Rng;

/// Adam state per atom matrix.
struct Adam {
    m: Vec<f32>,
    v: Vec<f32>,
    t: i32,
}

impl Adam {
    fn new(n: usize) -> Self {
        Adam { m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    fn step(&mut self, w: &mut [f32], g: &[f32], lr: f32) {
        self.t += 1;
        let (b1, b2, eps) = (0.9f32, 0.999f32, 1e-8f32);
        let bc1 = 1.0 - b1.powi(self.t);
        let bc2 = 1.0 - b2.powi(self.t);
        for i in 0..w.len() {
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g[i];
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g[i] * g[i];
            let mh = self.m[i] / bc1;
            let vh = self.v[i] / bc2;
            w[i] -= lr * mh / (vh.sqrt() + eps);
        }
    }
}

/// Training options (defaults mirror the paper's recipe at our scale).
pub struct TrainOpts {
    pub n_atoms: usize,
    pub sparsity: usize,
    pub epochs: usize,
    pub batch: usize,
    pub lr: f32,
    pub seed: u64,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts { n_atoms: 256, sparsity: 8, epochs: 8, batch: 128, lr: 1e-3, seed: 0 }
    }
}

/// Train one dictionary on `vectors` (n_vec × m, row-major).
/// Returns (dictionary, per-epoch mean squared reconstruction loss).
pub fn train_dictionary(vectors: &[f32], m: usize, opts: &TrainOpts) -> (Dictionary, Vec<f32>) {
    let n_vec = vectors.len() / m;
    assert!(n_vec > 0);
    let mut rng = Rng::new(opts.seed);
    // uniform init (PyTorch linear default), unit-norm atoms
    let lim = 1.0 / (m as f32).sqrt();
    let mut atoms: Vec<f32> = (0..opts.n_atoms * m)
        .map(|_| rng.range_f32(-lim, lim))
        .collect();
    for a in atoms.chunks_mut(m) {
        let nrm = norm2(a).max(1e-12);
        a.iter_mut().for_each(|x| *x /= nrm);
    }

    let mut adam = Adam::new(opts.n_atoms * m);
    let mut ws = OmpWorkspace::new(opts.n_atoms, m, opts.sparsity);
    let mut grad = vec![0.0f32; opts.n_atoms * m];
    let mut recon = vec![0.0f32; m];
    let mut order: Vec<usize> = (0..n_vec).collect();
    let total_steps = (opts.epochs * n_vec.div_ceil(opts.batch)).max(1);
    let mut step_i = 0usize;
    let mut losses = Vec::with_capacity(opts.epochs);

    for _ep in 0..opts.epochs {
        rng.shuffle(&mut order);
        let mut ep_loss = 0.0f64;
        let mut ep_n = 0usize;
        for chunk in order.chunks(opts.batch) {
            grad.fill(0.0);
            let mut batch_loss = 0.0f64;
            for &vi in chunk {
                let x = &vectors[vi * m..(vi + 1) * m];
                let code = omp_encode(&atoms, opts.n_atoms, m, x, opts.sparsity, 0.0, &mut ws);
                recon.fill(0.0);
                for (j, &id) in code.idx.iter().enumerate() {
                    axpy(&mut recon, code.val[j], &atoms[id as usize * m..(id as usize + 1) * m]);
                }
                // e = x − x̂ ; ∂L/∂atom_j = −2 y_j e
                let mut l = 0.0f32;
                for i in 0..m {
                    let e = x[i] - recon[i];
                    l += e * e;
                    recon[i] = e; // reuse as the error vector
                }
                batch_loss += l as f64;
                for (j, &id) in code.idx.iter().enumerate() {
                    axpy(
                        &mut grad[id as usize * m..(id as usize + 1) * m],
                        -2.0 * code.val[j],
                        &recon,
                    );
                }
            }
            let scale = 1.0 / chunk.len() as f32;
            grad.iter_mut().for_each(|g| *g *= scale);
            // remove the component of each atom's gradient parallel to it
            for (a, g) in atoms.chunks(m).zip(grad.chunks_mut(m)) {
                let par = dot(a, g);
                for i in 0..m {
                    g[i] -= par * a[i];
                }
            }
            // cosine-decayed Adam step, then renormalize
            let lr = opts.lr
                * 0.5
                * (1.0 + (std::f32::consts::PI * step_i as f32 / total_steps as f32).cos());
            adam.step(&mut atoms, &grad, lr);
            for a in atoms.chunks_mut(m) {
                let nrm = norm2(a).max(1e-8);
                a.iter_mut().for_each(|x| *x /= nrm);
            }
            ep_loss += batch_loss;
            ep_n += chunk.len();
            step_i += 1;
        }
        losses.push((ep_loss / ep_n as f64) as f32);
    }
    (Dictionary::new(m, opts.n_atoms, atoms), losses)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::omp::{omp_encode_alloc, rel_error};

    /// Synthetic data living in a union of a few low-dim subspaces — the
    /// structure Fig. 3 observes in real keys.
    fn subspace_data(rng: &mut Rng, n_vec: usize, m: usize, n_sub: usize, dim: usize) -> Vec<f32> {
        let bases: Vec<Vec<f32>> = (0..n_sub)
            .map(|_| {
                let mut b = rng.normal_vec(dim * m);
                for row in b.chunks_mut(m) {
                    let nrm = norm2(row).max(1e-12);
                    row.iter_mut().for_each(|x| *x /= nrm);
                }
                b
            })
            .collect();
        let mut out = vec![0.0; n_vec * m];
        for v in 0..n_vec {
            let b = &bases[rng.below(n_sub)];
            let x = &mut out[v * m..(v + 1) * m];
            for d in 0..dim {
                axpy(x, rng.normal(), &b[d * m..(d + 1) * m]);
            }
        }
        out
    }

    #[test]
    fn training_beats_random_dictionary() {
        let m = 16;
        let mut rng = Rng::new(21);
        let data = subspace_data(&mut rng, 400, m, 4, 3);
        let opts = TrainOpts { n_atoms: 64, sparsity: 4, epochs: 6, batch: 64, lr: 3e-3, seed: 1 };
        let (trained, losses) = train_dictionary(&data, m, &opts);
        assert!(
            losses[losses.len() - 1] < losses[0],
            "loss should fall: {losses:?}"
        );
        let random = Dictionary::random(m, 64, 99);
        let (mut e_t, mut e_r) = (0.0, 0.0);
        for v in 0..100 {
            let x = &data[v * m..(v + 1) * m];
            let ct = omp_encode_alloc(&trained.atoms, 64, m, x, 4, 0.0);
            let cr = omp_encode_alloc(&random.atoms, 64, m, x, 4, 0.0);
            e_t += rel_error(&trained.atoms, m, x, &ct);
            e_r += rel_error(&random.atoms, m, x, &cr);
        }
        assert!(e_t < e_r, "trained {e_t} !< random {e_r}");
    }

    #[test]
    fn atoms_stay_unit_norm() {
        let m = 8;
        let mut rng = Rng::new(2);
        let data = rng.normal_vec(64 * m);
        let opts = TrainOpts { n_atoms: 32, sparsity: 3, epochs: 2, batch: 32, lr: 1e-2, seed: 4 };
        let (d, _) = train_dictionary(&data, m, &opts);
        for a in 0..d.n {
            assert!((norm2(d.atom(a)) - 1.0).abs() < 1e-4);
        }
    }
}
