//! Integration tests across modules: engine × every cache backend,
//! compression-vs-accuracy invariants, serving end-to-end, eval harness
//! determinism. These run on a synthetic tiny model (no artifacts needed);
//! artifact-dependent tests live in `tests/artifacts.rs`.

use std::sync::Arc;

use lexico::cache::factory::{build_cache, CacheContext};
use lexico::cache::full::FullCache;
use lexico::cache::CacheShape;
use lexico::dict::{Dictionary, DictionarySet};
use lexico::model::testutil::tiny_weights;
use lexico::model::Engine;
use lexico::tasks::Task;
use lexico::util::rng::Rng;

fn tiny_dicts(shape: CacheShape, n_atoms: usize) -> Arc<DictionarySet> {
    Arc::new(DictionarySet {
        keys: (0..shape.n_layers)
            .map(|i| Dictionary::random(shape.head_dim, n_atoms, 1000 + i as u64))
            .collect(),
        values: (0..shape.n_layers)
            .map(|i| Dictionary::random(shape.head_dim, n_atoms, 2000 + i as u64))
            .collect(),
    })
}

// NB: the tiny test model has head_dim m=8, so compression demands s ≤ 2
// ((3s+2)/(2m) < 1 needs s < 4.7; meaningful compression needs less).
const ALL_SPECS: &[&str] = &[
    "full",
    "lexico:s=2,nb=8",
    "lexico:s=2,nb=8,fp16",
    "lexico:s=2,nb=0",
    "lexico:s=2,nb=8,delta=0.4",
    "lexico:s=1,nb=4,adaptive=16:0.35",
    "kivi:bits=2,g=8,nb=8",
    "kivi:bits=4,g=8,nb=8",
    "pertoken:bits=4,g=8,nb=2",
    "pertoken:bits=8,g=8,nb=0",
    "zipcache:hi=4,lo=2,g=8,frac=0.25,nb=8",
    "snapkv:cap=24,win=4",
    "pyramidkv:cap=24,win=4",
];

/// Every backend must run generation end-to-end without panicking and
/// report a sane KV ratio.
#[test]
fn every_backend_generates() {
    let engine = Engine::new(tiny_weights(40));
    let dicts = tiny_dicts(engine.shape(), 64);
    let ctx = CacheContext::new(engine.shape(), Some(dicts));
    let mut rng = Rng::new(0);
    let prompt: Vec<u32> = (0..40).map(|_| 3 + rng.below(50) as u32).collect();
    for spec in ALL_SPECS {
        let mut cache = build_cache(spec, &ctx).unwrap();
        let out = engine.generate(&prompt, 6, None, &mut *cache);
        assert_eq!(out.len(), 6, "{spec}");
        let ratio = cache.kv_ratio();
        assert!(ratio > 0.0 && ratio <= 1.3, "{spec}: ratio {ratio}");
        assert_eq!(cache.tokens(), 40 + 5, "{spec}");
    }
}

/// Compression backends must actually compress on a long context.
#[test]
fn compressing_backends_report_compression() {
    let engine = Engine::new(tiny_weights(41));
    let dicts = tiny_dicts(engine.shape(), 64);
    let ctx = CacheContext::new(engine.shape(), Some(dicts));
    let mut rng = Rng::new(1);
    let prompt: Vec<u32> = (0..100).map(|_| 3 + rng.below(50) as u32).collect();
    for spec in &ALL_SPECS[1..] {
        if spec.starts_with("pertoken:bits=8") {
            continue; // int8 is allowed to be "large"
        }
        let mut cache = build_cache(spec, &ctx).unwrap();
        let _ = engine.generate(&prompt, 4, None, &mut *cache);
        assert!(
            cache.kv_ratio() < 0.95,
            "{spec}: ratio {} not compressed",
            cache.kv_ratio()
        );
    }
}

/// With an orthonormal dictionary and s = head_dim, Lexico reconstruction
/// is exact (up to fp16 coefs) → generated tokens must match the full cache.
#[test]
fn lexico_exact_dictionary_matches_full_cache_generation() {
    let engine = Engine::new(tiny_weights(42));
    let shape = engine.shape();
    let m = shape.head_dim;
    // orthonormal basis dictionary
    let mut atoms = vec![0.0; m * m];
    for i in 0..m {
        atoms[i * m + i] = 1.0;
    }
    let d = Dictionary::new(m, m, atoms);
    let dicts = Arc::new(DictionarySet {
        keys: vec![d.clone(); shape.n_layers],
        values: vec![d; shape.n_layers],
    });
    let ctx = CacheContext::new(shape, Some(dicts));
    let mut rng = Rng::new(2);
    let prompt: Vec<u32> = (0..30).map(|_| 3 + rng.below(50) as u32).collect();
    let mut lex = build_cache(&format!("lexico:s={m},nb=4,fp16"), &ctx).unwrap();
    let mut full = FullCache::new(shape);
    let a = engine.generate(&prompt, 8, None, &mut *lex);
    let b = engine.generate(&prompt, 8, None, &mut full);
    assert_eq!(a, b, "exact-reconstruction Lexico must match full cache");
}

/// Lower sparsity ⇒ smaller cache (memory monotonicity in s).
#[test]
fn lexico_memory_monotone_in_sparsity() {
    let engine = Engine::new(tiny_weights(43));
    let dicts = tiny_dicts(engine.shape(), 64);
    let ctx = CacheContext::new(engine.shape(), Some(dicts));
    let mut rng = Rng::new(3);
    let prompt: Vec<u32> = (0..80).map(|_| 3 + rng.below(50) as u32).collect();
    let mut prev = 0.0;
    for s in [1usize, 2, 4, 8] {
        let mut cache = build_cache(&format!("lexico:s={s},nb=4"), &ctx).unwrap();
        let _ = engine.generate(&prompt, 4, None, &mut *cache);
        let r = cache.kv_ratio();
        assert!(r > prev, "s={s}: {r} !> {prev}");
        prev = r;
    }
}

/// The eval harness is deterministic for a fixed seed.
#[test]
fn eval_harness_deterministic() {
    let engine = Engine::new(tiny_weights(44));
    let r1 = lexico::eval::evaluate(
        &engine, None, "pertoken:bits=8,g=8",
        &lexico::eval::EvalConfig::new(Task::Sort, 4, 99),
    )
    .unwrap();
    let r2 = lexico::eval::evaluate(
        &engine, None, "pertoken:bits=8,g=8",
        &lexico::eval::EvalConfig::new(Task::Sort, 4, 99),
    )
    .unwrap();
    assert_eq!(r1.score, r2.score);
    assert_eq!(r1.kv_ratio, r2.kv_ratio);
}

/// int8 per-token quantization is near-lossless: its generations should
/// match the full cache almost always on a random tiny model.
#[test]
fn int8_nearly_lossless_generation() {
    let engine = Engine::new(tiny_weights(45));
    let ctx = CacheContext::new(engine.shape(), None);
    let mut rng = Rng::new(4);
    let mut agree = 0;
    let total = 10;
    for _ in 0..total {
        let prompt: Vec<u32> = (0..30).map(|_| 3 + rng.below(50) as u32).collect();
        let mut q = build_cache("pertoken:bits=8,g=8,nb=0", &ctx).unwrap();
        let mut f = FullCache::new(engine.shape());
        let a = engine.generate(&prompt, 6, None, &mut *q);
        let b = engine.generate(&prompt, 6, None, &mut f);
        agree += (a == b) as usize;
    }
    assert!(agree >= total - 1, "int8 agreed only {agree}/{total}");
}

/// Eviction methods keep memory bounded as the prompt grows; Lexico keeps
/// (amortized) per-token cost constant. Both invariants checked here.
#[test]
fn memory_scaling_invariants() {
    let engine = Engine::new(tiny_weights(46));
    let dicts = tiny_dicts(engine.shape(), 64);
    let ctx = CacheContext::new(engine.shape(), Some(dicts));
    let mut rng = Rng::new(5);
    let prompt_a: Vec<u32> = (0..40).map(|_| 3 + rng.below(50) as u32).collect();
    let prompt_b: Vec<u32> = (0..100).map(|_| 3 + rng.below(50) as u32).collect();
    // snapkv: absolute bytes bounded by capacity regardless of prompt len
    let (mut ca, mut cb) = (
        build_cache("snapkv:cap=16,win=4", &ctx).unwrap(),
        build_cache("snapkv:cap=16,win=4", &ctx).unwrap(),
    );
    let _ = engine.generate(&prompt_a, 2, None, &mut *ca);
    let _ = engine.generate(&prompt_b, 2, None, &mut *cb);
    assert!((ca.mem_bytes() - cb.mem_bytes()).abs() < 1.0);
    // lexico: ratio roughly constant in prompt length
    let (mut la, mut lb) = (
        build_cache("lexico:s=4,nb=8", &ctx).unwrap(),
        build_cache("lexico:s=4,nb=8", &ctx).unwrap(),
    );
    let _ = engine.generate(&prompt_a, 2, None, &mut *la);
    let _ = engine.generate(&prompt_b, 2, None, &mut *lb);
    assert!(lb.kv_ratio() < la.kv_ratio() + 0.05);
}

/// Serving end-to-end with the Lexico backend under concurrent load.
#[test]
fn serve_with_lexico_backend() {
    use lexico::server::batcher::{run, BatcherConfig};
    use lexico::server::metrics::Metrics;
    use lexico::server::{Job, Request};
    use std::sync::mpsc::channel;
    use std::sync::Mutex;

    let engine = Arc::new(Engine::new(tiny_weights(47)));
    let dicts = tiny_dicts(engine.shape(), 64);
    let metrics = Arc::new(Mutex::new(Metrics::new()));
    let (tx, rx) = channel();
    let m2 = metrics.clone();
    let cfg = BatcherConfig {
        default_method: "lexico:s=4,nb=8".into(),
        kv_budget_bytes: 8.0 * 1024.0 * 1024.0,
        max_sessions: 8,
        ..Default::default()
    };
    let handle = std::thread::spawn(move || run(engine, Some(dicts), cfg, rx, m2));
    let mut replies = Vec::new();
    for i in 0..6 {
        let (rtx, rrx) = channel();
        tx.send(Job::new(Request::greedy(i, format!("k0{i}=v42;k0{i}?"), 6, ""), rtx))
            .unwrap();
        replies.push(rrx);
    }
    drop(tx);
    for r in replies {
        let resp = r.recv_timeout(std::time::Duration::from_secs(60)).unwrap();
        assert!(resp.error.is_none());
        assert!(resp.kv_ratio > 0.0 && resp.kv_ratio <= 1.0);
    }
    handle.join().unwrap().unwrap();
    let m = metrics.lock().unwrap();
    assert_eq!(m.completed, 6);
    assert!(m.kv_ratios.iter().all(|&r| r < 1.0), "lexico should compress");
}
