//! Golden-transcript regression tests: pin the exact greedy token streams
//! of every cache backend × model size on fixed seeds, and enforce the
//! fork-parity contract of the shared-prefix serving path.
//!
//! **Snapshot mechanics.** The pinned streams live in
//! `tests/goldens/transcripts.snap`. When the file is missing (fresh
//! checkout before anyone recorded, or after an intentional `rm` to
//! re-pin) the test records the current streams and passes with a notice;
//! when present, any deviation — a kernel tweak, a cache refactor, an OMP
//! change that silently alters decode output — fails loudly with a diff
//! hint and writes `transcripts.snap.new` for inspection. CI runs the
//! suite twice back to back so a fresh runner still verifies record ≡
//! replay; committing the snapshot pins streams across machines.
//!
//! **Fork parity** needs no stored constants: a forked session's
//! continuation must be token-identical to the original's, and
//! `fork(prefix prototype)` + suffix prefill + greedy decode must be
//! token-identical to a cold session prefilled on the full prompt — for
//! every backend (score-state backends are exercised in regimes where
//! split prefill is exact; their `caps().split_prefill_exact` contract is
//! asserted, which is what keeps the production prefix cache away from
//! the inexact regimes).

use std::path::PathBuf;
use std::sync::Arc;

use lexico::cache::factory::{build_cache, CacheContext};
use lexico::cache::{CacheShape, KvCache};
use lexico::dict::{Dictionary, DictionarySet};
use lexico::model::testutil::{tiny_weights, tiny_weights_deep};
use lexico::model::Engine;
use lexico::tasks;
use lexico::tensor::argmax;

const N_DECODE: usize = 16;
const PROMPT: &str = "k01=v42;k07=v13;k01?";

/// Backend specs pinned by the snapshot (every backend family, all three
/// coefficient modes for lexico).
const SPECS: [&str; 9] = [
    "full",
    "lexico:s=2,nb=4",
    "lexico:s=2,nb=4,fp16",
    "lexico:s=2,nb=4,sign",
    "kivi:bits=4,g=4,nb=4",
    "pertoken:bits=8,g=8,nb=2",
    "zipcache:hi=4,lo=2,g=8,frac=0.25,nb=8",
    "snapkv:cap=24,win=4",
    "pyramidkv:cap=24,win=4",
];

fn tiny_dicts(shape: CacheShape, n_atoms: usize) -> Arc<DictionarySet> {
    Arc::new(DictionarySet {
        keys: (0..shape.n_layers)
            .map(|i| Dictionary::random(shape.head_dim, n_atoms, 1000 + i as u64))
            .collect(),
        values: (0..shape.n_layers)
            .map(|i| Dictionary::random(shape.head_dim, n_atoms, 2000 + i as u64))
            .collect(),
    })
}

fn engines() -> Vec<(&'static str, Engine)> {
    vec![
        ("S", Engine::new(tiny_weights(101))),
        ("deep", Engine::new(tiny_weights_deep(202))),
    ]
}

fn ctx_for(engine: &Engine) -> CacheContext {
    CacheContext::new(engine.shape(), Some(tiny_dicts(engine.shape(), 64)))
}

fn prompt_ids() -> Vec<u32> {
    let mut ids = vec![tasks::BOS];
    ids.extend(tasks::encode(PROMPT));
    ids
}

/// Greedy generator state: `tok` is the next token to emit, the cache
/// holds positions `0..pos`.
#[derive(Clone, Copy)]
struct Gen {
    tok: u32,
    pos: usize,
}

/// Emit `n` tokens greedily, advancing the cache.
fn advance(engine: &Engine, cache: &mut dyn KvCache, g: &mut Gen, n: usize) -> Vec<u32> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(g.tok);
        let logits = engine.decode_step(g.tok, g.pos, cache);
        g.tok = argmax(&logits) as u32;
        g.pos += 1;
    }
    out
}

fn cold_stream(engine: &Engine, ctx: &CacheContext, spec: &str, n: usize) -> Vec<u32> {
    let ids = prompt_ids();
    let mut cache = build_cache(spec, ctx).unwrap();
    let logits = engine.prefill(&ids, &mut *cache);
    let mut g = Gen { tok: argmax(&logits) as u32, pos: ids.len() };
    advance(engine, &mut *cache, &mut g, n)
}

fn snap_path(suffix: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(format!("tests/goldens/transcripts{suffix}"))
}

#[test]
fn golden_transcripts_pin_greedy_decode_streams() {
    let render = || {
        let mut current = String::new();
        for (size, engine) in engines() {
            let ctx = ctx_for(&engine);
            for spec in SPECS {
                let stream = cold_stream(&engine, &ctx, spec, N_DECODE);
                let toks: Vec<String> = stream.iter().map(u32::to_string).collect();
                current.push_str(&format!("{size}/{spec}: {}\n", toks.join(" ")));
            }
        }
        current
    };
    let current = render();
    if lexico::tensor::simd::fast_math_requested() {
        // The snapshot pins the *canonical* tier; fast-math is excluded
        // from the bitwise contract (it's pinned by tolerance goldens in
        // tensor::simd instead). Still assert the fast tier is internally
        // deterministic: record ≡ replay within this process.
        assert_eq!(current, render(), "fast-math decode streams are not reproducible");
        eprintln!(
            "LEXICO_FAST_MATH set: skipping canonical snapshot compare \
             (fast tier verified record ≡ replay instead)"
        );
        return;
    }
    if lexico::omp::gram_omp_requested() {
        // Same contract as fast-math: the Gram pursuit is tolerance-equal
        // to canonical (pinned by the omp::gram parity suite), so the
        // canonical snapshot doesn't apply — but the tier must still be
        // reproducible: record ≡ replay within this process.
        assert_eq!(current, render(), "gram-omp decode streams are not reproducible");
        eprintln!(
            "LEXICO_GRAM_OMP set: skipping canonical snapshot compare \
             (gram tier verified record ≡ replay instead)"
        );
        return;
    }
    if std::env::var("LEXICO_COEF_MODE").is_ok_and(|v| !v.is_empty()) {
        // A global coefficient-mode override retargets every lexico spec
        // that left its mode at the default, so the canonical snapshot
        // doesn't apply — the overridden mode must still be bitwise
        // reproducible: record ≡ replay within this process. (CI runs the
        // suite twice back to back, so a second whole-process render is
        // verified against this one too.)
        assert_eq!(current, render(), "coef-mode decode streams are not reproducible");
        eprintln!(
            "LEXICO_COEF_MODE set: skipping canonical snapshot compare \
             (override mode verified record ≡ replay instead)"
        );
        return;
    }
    let path = snap_path(".snap");
    match std::fs::read_to_string(&path) {
        Ok(pinned) if !pinned.trim().is_empty() => {
            if pinned != current {
                let new_path = snap_path(".snap.new");
                let _ = std::fs::write(&new_path, &current);
                let mismatch: Vec<&str> = pinned
                    .lines()
                    .zip(current.lines())
                    .filter(|(a, b)| a != b)
                    .map(|(a, _)| a.split(':').next().unwrap_or(a))
                    .collect();
                panic!(
                    "greedy decode streams changed for {mismatch:?} — a kernel or cache \
                     change altered decode output. If intentional, replace {} with {} \
                     (or delete the .snap and re-run to re-record).",
                    path.display(),
                    new_path.display()
                );
            }
        }
        _ => {
            std::fs::create_dir_all(path.parent().unwrap()).unwrap();
            std::fs::write(&path, &current).unwrap();
            eprintln!("recorded golden transcripts at {}", path.display());
        }
    }
}

/// A fork taken mid-decode must continue token-identically to the
/// original, and mutating the fork must not perturb the original — for
/// every backend, including adaptive lexico (deep-copied overlay).
#[test]
fn fork_midstream_continuation_is_token_identical_for_every_backend() {
    let mut forked_specs = SPECS.to_vec();
    forked_specs.push("lexico:s=2,nb=4,adaptive=16:0.3");
    for (size, engine) in engines() {
        let ctx = ctx_for(&engine);
        for &spec in &forked_specs {
            let reference = cold_stream(&engine, &ctx, spec, 12);

            let ids = prompt_ids();
            let mut cache = build_cache(spec, &ctx).unwrap();
            let logits = engine.prefill(&ids, &mut *cache);
            let mut g = Gen { tok: argmax(&logits) as u32, pos: ids.len() };
            let head = advance(&engine, &mut *cache, &mut g, 4);
            assert_eq!(head, reference[..4], "{size}/{spec}: pre-fork drift");

            let mut fork = cache.fork();
            let mut gf = g; // generator state forks with the cache
            let fork_tail = advance(&engine, &mut *fork, &mut gf, 8);
            assert_eq!(fork_tail, reference[4..12], "{size}/{spec}: fork diverged");
            // push the fork further so it mutates past the shared point
            let _ = advance(&engine, &mut *fork, &mut gf, 2);

            let tail = advance(&engine, &mut *cache, &mut g, 8);
            assert_eq!(
                tail,
                &reference[4..12],
                "{size}/{spec}: fork mutation leaked into the original"
            );
        }
    }
}

/// The prefix-cache serving path, end to end at the engine level: fork a
/// prefix prototype, prefill only the suffix, decode greedily — the token
/// stream must be identical to a cold session prefilled on the whole
/// prompt. Score-state backends run in regimes where their prefill
/// decisions cannot differ (under eviction capacity / inside the
/// residual window); their `caps().split_prefill_exact` must still be
/// `false`, which is what keeps the production prefix cache away from the
/// regimes where they *would* diverge.
#[test]
fn fork_plus_suffix_prefill_matches_cold_prefill_for_every_backend() {
    // (spec, exact): `exact` mirrors CacheCaps::split_prefill_exact
    let cases: [(&str, bool); 9] = [
        ("full", true),
        ("lexico:s=2,nb=4", true),
        ("lexico:s=2,nb=4,fp16", true),
        ("lexico:s=2,nb=4,sign", true),
        ("kivi:bits=4,g=4,nb=4", true),
        ("pertoken:bits=8,g=8,nb=2", true),
        // nothing spills within the test horizon → salience never consulted
        ("zipcache:hi=4,lo=2,g=8,frac=0.25,nb=96", false),
        // prompt stays under capacity → no eviction decision to differ
        ("snapkv:cap=100,win=4", false),
        ("pyramidkv:cap=100,win=4", false),
    ];
    for (size, engine) in engines() {
        let ctx = ctx_for(&engine);
        let ids = prompt_ids();
        let split = 12; // prefix "k01=v42;k07" ++ suffix "=v13;k01?"
        for (spec, exact) in cases {
            assert_eq!(
                build_cache(spec, &ctx).unwrap().caps().split_prefill_exact,
                exact,
                "{spec}: split_prefill_exact contract"
            );
            // cold reference
            let mut cold = build_cache(spec, &ctx).unwrap();
            let logits = engine.prefill(&ids, &mut *cold);
            let mut gc = Gen { tok: argmax(&logits) as u32, pos: ids.len() };
            let want = advance(&engine, &mut *cold, &mut gc, 12);

            // prototype prefilled on the prefix, then fork + suffix
            let mut proto = build_cache(spec, &ctx).unwrap();
            let (_, state) = engine.prefill_capture(&ids[..split], &mut *proto);
            let mut sess = proto.fork();
            let logits = engine.prefill_suffix(&state, &ids[split..], &mut *sess);
            let mut gs = Gen { tok: argmax(&logits) as u32, pos: ids.len() };
            let got = advance(&engine, &mut *sess, &mut gs, 12);

            assert_eq!(got, want, "{size}/{spec}: prefix-cache path altered the stream");
            assert_eq!(
                sess.mem_bytes(),
                cold.mem_bytes(),
                "{size}/{spec}: split prefill left a different footprint"
            );
            assert_eq!(sess.tokens(), cold.tokens(), "{size}/{spec}");
        }
    }
}
