//! Chunked-prefill parity: [`Engine::prefill_chunk`] with ANY partition of
//! the prompt — C ∈ {1, 7, 256, len} — must reproduce monolithic prefill
//! bitwise for every backend whose `split_prefill_exact` holds: identical
//! final logits, identical compressed cache bytes, and a bitwise-identical
//! decode trace afterwards. This is the contract that lets the batcher
//! schedule prefill one budgeted chunk per round (DESIGN.md §9) without
//! perturbing a single pinned transcript.

use std::sync::Arc;

use lexico::cache::factory::{build_cache, CacheContext};
use lexico::cache::{CacheShape, KvCache};
use lexico::dict::{Dictionary, DictionarySet};
use lexico::model::testutil::{tiny_weights, tiny_weights_deep};
use lexico::model::{Engine, PrefixState};
use lexico::tensor::argmax;
use lexico::util::rng::Rng;

/// Backends the chunked scheduler serves chunked (split-exact families,
/// every lexico coefficient mode).
const SPLIT_EXACT_SPECS: [&str; 7] = [
    "full",
    "lexico:s=2,nb=4",
    "lexico:s=2,nb=4,fp16",
    "lexico:s=2,nb=4,sign",
    "lexico:s=4,nb=8",
    "kivi:bits=4,g=4,nb=4",
    "pertoken:bits=8,g=8,nb=2",
];

fn tiny_dicts(shape: CacheShape, n_atoms: usize) -> Arc<DictionarySet> {
    Arc::new(DictionarySet {
        keys: (0..shape.n_layers)
            .map(|i| Dictionary::random(shape.head_dim, n_atoms, 4000 + i as u64))
            .collect(),
        values: (0..shape.n_layers)
            .map(|i| Dictionary::random(shape.head_dim, n_atoms, 5000 + i as u64))
            .collect(),
    })
}

/// Greedy-decode `n` steps from `logits`, returning every logit vector
/// (bitwise comparison material for the post-prefill continuation).
fn decode_trace(
    eng: &Engine,
    cache: &mut dyn KvCache,
    logits: Vec<f32>,
    pos0: usize,
    n: usize,
) -> Vec<Vec<f32>> {
    let mut out = vec![logits];
    let mut pos = pos0;
    for _ in 0..n {
        let tok = argmax(out.last().unwrap()) as u32;
        let l = eng.decode_step(tok, pos, cache);
        out.push(l);
        pos += 1;
    }
    out
}

#[test]
fn chunked_prefill_is_bitwise_identical_for_every_split_exact_backend() {
    for (wi, weights) in [tiny_weights(55), tiny_weights_deep(56)].into_iter().enumerate() {
        let eng = Engine::new(weights);
        let ctx = CacheContext::new(eng.shape(), Some(tiny_dicts(eng.shape(), 64)));
        let mut rng = Rng::new(77 + wi as u64);
        // long enough that lexico overflows its residual buffer and
        // compresses mid-prompt — across chunk boundaries
        let prompt: Vec<u32> = (0..40).map(|_| 3 + rng.below(50) as u32).collect();

        for spec in SPLIT_EXACT_SPECS {
            let mut mono = build_cache(spec, &ctx).unwrap();
            assert!(mono.caps().split_prefill_exact, "{spec} must be split-exact");
            let l_mono = eng.prefill(&prompt, &mut *mono);
            let bytes_mono = mono.mem_bytes();
            let trace_mono = decode_trace(&eng, &mut *mono, l_mono.clone(), prompt.len(), 3);

            for chunk in [1usize, 7, 256, prompt.len()] {
                let mut cache = build_cache(spec, &ctx).unwrap();
                let mut state = PrefixState::empty(eng.shape().n_layers);
                let mut logits = Vec::new();
                for c in prompt.chunks(chunk) {
                    logits = eng.prefill_chunk(&mut state, c, &mut *cache);
                }
                assert_eq!(state.len(), prompt.len());
                assert_eq!(
                    logits, l_mono,
                    "{spec} (model {wi}): C={chunk} final logits diverged"
                );
                assert_eq!(
                    cache.mem_bytes(),
                    bytes_mono,
                    "{spec} (model {wi}): C={chunk} cache bytes diverged"
                );
                assert_eq!(cache.tokens(), prompt.len(), "{spec}: C={chunk}");
                let trace = decode_trace(&eng, &mut *cache, logits, prompt.len(), 3);
                assert_eq!(
                    trace, trace_mono,
                    "{spec} (model {wi}): C={chunk} post-prefill decode diverged"
                );
            }
        }
    }
}

#[test]
fn chunked_prefill_state_matches_monolithic_capture() {
    // The rolling PrefixState a chunked prefill maintains must be exactly
    // the state a monolithic capture produces — it is what the batcher
    // seals into the shared-prefix cache when the prompt qualifies.
    let eng = Engine::new(tiny_weights(57));
    let mut rng = Rng::new(91);
    let prompt: Vec<u32> = (0..23).map(|_| 3 + rng.below(50) as u32).collect();
    let mut c1 = lexico::cache::full::FullCache::new(eng.shape());
    let (_, st_mono) = eng.prefill_capture(&prompt, &mut c1);
    for chunk in [1usize, 7, 256] {
        let mut c2 = lexico::cache::full::FullCache::new(eng.shape());
        let mut state = PrefixState::empty(eng.shape().n_layers);
        for c in prompt.chunks(chunk) {
            let _ = eng.prefill_chunk(&mut state, c, &mut c2);
        }
        assert_eq!(state.tokens, st_mono.tokens, "C={chunk}");
        assert_eq!(state.ks, st_mono.ks, "C={chunk}: K rows diverged");
        assert_eq!(state.vs, st_mono.vs, "C={chunk}: V rows diverged");
        assert_eq!(state.logits, st_mono.logits, "C={chunk}");
    }
}

#[test]
fn non_split_exact_backends_reject_nothing_but_differ_when_chunked() {
    // SnapKV scores its observation window over whatever each ingest call
    // delivers, so chunking is NOT bitwise-neutral for it — which is
    // exactly why the batcher prefills such backends monolithically
    // (asserted at the batcher level in server::batcher::tests). Here we
    // pin the trait flag that gates that decision.
    let eng = Engine::new(tiny_weights(58));
    let ctx = CacheContext::new(eng.shape(), Some(tiny_dicts(eng.shape(), 64)));
    for spec in ["snapkv:cap=24,win=4", "pyramidkv:cap=24,win=4"] {
        let cache = build_cache(spec, &ctx).unwrap();
        assert!(
            !cache.caps().split_prefill_exact,
            "{spec}: observation-window backends must opt out of chunked prefill"
        );
    }
}
