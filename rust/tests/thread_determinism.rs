//! Exec-layer determinism tests: the worker pool schedules *work*, never
//! *values*, so greedy token streams, batched decode, and served responses
//! must be bitwise/token identical at every thread count. These tests pin
//! engines (and their caches) to explicit 1-, 2- and 4-thread pools and
//! compare everything against the T = 1 reference — the same contract the
//! golden-transcript and batch-parity suites verify implicitly when CI runs
//! them under `LEXICO_THREADS=4`.

use std::sync::{Arc, Mutex};

use lexico::cache::factory::{build_cache, CacheContext};
use lexico::cache::{CacheShape, KvCache};
use lexico::dict::{Dictionary, DictionarySet};
use lexico::exec::ExecPool;
use lexico::model::testutil::tiny_weights;
use lexico::model::Engine;
use lexico::server::batcher::{Batcher, BatcherConfig};
use lexico::server::metrics::Metrics;
use lexico::server::{Job, Request, Response};
use lexico::tensor::argmax;
use lexico::util::rng::Rng;

const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

/// Backend specs covering every compression family (and both lexico
/// precisions) — the same families the golden transcripts pin.
const SPECS: [&str; 9] = [
    "full",
    "lexico:s=2,nb=4",
    "lexico:s=2,nb=4,fp16",
    "lexico:s=2,nb=4,sign",
    "lexico:s=2,nb=4,adaptive=16:0.3",
    "kivi:bits=4,g=4,nb=4",
    "pertoken:bits=8,g=8,nb=2",
    "snapkv:cap=24,win=4",
    "pyramidkv:cap=24,win=4",
];

fn tiny_dicts(shape: CacheShape, n_atoms: usize) -> Arc<DictionarySet> {
    Arc::new(DictionarySet {
        keys: (0..shape.n_layers)
            .map(|i| Dictionary::random(shape.head_dim, n_atoms, 1000 + i as u64))
            .collect(),
        values: (0..shape.n_layers)
            .map(|i| Dictionary::random(shape.head_dim, n_atoms, 2000 + i as u64))
            .collect(),
    })
}

fn engine_with_threads(threads: usize) -> Engine {
    Engine::with_pool(tiny_weights(101), Arc::new(ExecPool::new(threads)))
}

/// Prefill + greedy decode `n` tokens, with the cache pinned to the
/// engine's pool (the batcher's wiring). Returns (stream, logit trace of
/// the first decode step).
fn greedy_stream(engine: &Engine, spec: &str, prompt: &[u32], n: usize) -> (Vec<u32>, Vec<f32>) {
    let mut ctx = CacheContext::new(engine.shape(), Some(tiny_dicts(engine.shape(), 64)));
    ctx.runtime = ctx.runtime.with_pool(engine.pool().clone());
    let mut cache = build_cache(spec, &ctx).unwrap();
    let logits = engine.prefill(prompt, &mut *cache);
    let mut tok = argmax(&logits) as u32;
    let mut pos = prompt.len();
    let mut out = Vec::with_capacity(n);
    let mut first_logits = Vec::new();
    for i in 0..n {
        out.push(tok);
        let logits = engine.decode_step(tok, pos, &mut *cache);
        if i == 0 {
            first_logits = logits.clone();
        }
        tok = argmax(&logits) as u32;
        pos += 1;
    }
    (out, first_logits)
}

#[test]
fn greedy_streams_are_bitwise_identical_across_thread_counts() {
    let prompt: Vec<u32> = vec![1, 5, 9, 2, 7, 3, 8, 4, 6, 2, 5, 9];
    let reference: Vec<(Vec<u32>, Vec<f32>)> = {
        let eng = engine_with_threads(1);
        SPECS.iter().map(|spec| greedy_stream(&eng, spec, &prompt, 14)).collect()
    };
    for &threads in &THREAD_COUNTS[1..] {
        let eng = engine_with_threads(threads);
        for (si, spec) in SPECS.iter().enumerate() {
            let (stream, logits) = greedy_stream(&eng, spec, &prompt, 14);
            assert_eq!(
                stream, reference[si].0,
                "{spec}: token stream diverged at T={threads}"
            );
            assert_eq!(
                logits, reference[si].1,
                "{spec}: decode logits not bitwise identical at T={threads}"
            );
        }
    }
}

#[test]
fn decode_batch_is_token_identical_across_thread_counts() {
    // Mixed backends decoded in ONE batch per round, at T ∈ {1, 2, 4}:
    // every thread count must produce the T=1 streams (this also exercises
    // the per-session fan-out shards and the parallel batched-OMP overflow
    // compression, since the lexico sessions overflow their buffers).
    let prompts: Vec<Vec<u32>> = {
        let mut rng = Rng::new(7);
        (0..SPECS.len()).map(|i| (0..12 + 4 * i).map(|_| 3 + rng.below(50) as u32).collect()).collect()
    };
    let run = |threads: usize| -> Vec<Vec<u32>> {
        let eng = engine_with_threads(threads);
        let mut ctx = CacheContext::new(eng.shape(), Some(tiny_dicts(eng.shape(), 64)));
        ctx.runtime = ctx.runtime.with_pool(eng.pool().clone());
        let mut caches: Vec<Box<dyn KvCache>> = Vec::new();
        let mut toks: Vec<u32> = Vec::new();
        let mut poss: Vec<usize> = Vec::new();
        let mut streams: Vec<Vec<u32>> = Vec::new();
        for (spec, prompt) in SPECS.iter().zip(&prompts) {
            let mut cache = build_cache(spec, &ctx).unwrap();
            let logits = eng.prefill(prompt, &mut *cache);
            caches.push(cache);
            toks.push(argmax(&logits) as u32);
            poss.push(prompt.len());
            streams.push(vec![*toks.last().unwrap()]);
        }
        for _round in 0..10 {
            let mut refs: Vec<&mut dyn KvCache> = caches.iter_mut().map(|c| &mut **c).collect();
            let logits = eng.decode_batch(&toks, &poss, &mut refs);
            drop(refs);
            for i in 0..SPECS.len() {
                toks[i] = argmax(&logits[i]) as u32;
                poss[i] += 1;
                streams[i].push(toks[i]);
            }
        }
        streams
    };
    let reference = run(1);
    for &threads in &THREAD_COUNTS[1..] {
        let streams = run(threads);
        for (si, spec) in SPECS.iter().enumerate() {
            assert_eq!(
                streams[si], reference[si],
                "{spec}: batched decode diverged at T={threads}"
            );
        }
    }
}

#[test]
fn batcher_serves_identical_responses_at_every_thread_count() {
    // The whole serving path — admission prefill, prefix cache, fan-out,
    // batched decode rounds — driven synchronously per thread count; the
    // reply texts (primary + alternates) must match exactly.
    let run = |threads: usize| -> Vec<Response> {
        let engine = Arc::new(engine_with_threads(threads));
        let dicts = tiny_dicts(engine.shape(), 64);
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let cfg = BatcherConfig {
            default_method: "lexico:s=2,nb=4".into(),
            prefix_min_tokens: 4,
            ..Default::default()
        };
        let mut b = Batcher::new(engine, Some(dicts), cfg, metrics);
        assert_eq!(b.pool().threads(), threads);
        let reqs = [
            Request::greedy(1, "k01=v11;k02=v22;k03=v33;k04=v44;", 6, ""),
            Request::greedy(2, "k01=v11;k02=v22;k03=v33;k04=v44;k02?", 6, ""),
            Request::greedy(3, "1+2=", 5, "full"),
            Request { fanout: 3, ..Request::greedy(4, "2,7,4>", 5, "") },
        ];
        let mut replies = Vec::new();
        for r in reqs {
            let (tx, rx) = std::sync::mpsc::channel();
            b.enqueue(Job::new(r, tx));
            replies.push(rx);
        }
        for _ in 0..128 {
            if !b.has_work() {
                break;
            }
            b.round();
        }
        replies.into_iter().map(|r| r.try_recv().expect("reply pending")).collect()
    };
    let reference = run(1);
    for &threads in &THREAD_COUNTS[1..] {
        let got = run(threads);
        assert_eq!(got.len(), reference.len());
        for (g, want) in got.iter().zip(&reference) {
            assert!(g.error.is_none(), "T={threads}: {:?}", g.error);
            assert_eq!(g.text, want.text, "T={threads}: primary stream diverged");
            assert_eq!(g.alts, want.alts, "T={threads}: fan-out alternates diverged");
            assert_eq!(g.n_generated, want.n_generated, "T={threads}");
            assert_eq!(g.prefix_hit, want.prefix_hit, "T={threads}");
        }
    }
}

#[test]
fn prefill_capture_and_suffix_resume_are_thread_invariant() {
    // The shared-prefix serving path under threads: captured prefix state
    // and suffix-resumed logits must be bitwise equal to the T=1 run.
    let toks: Vec<u32> = vec![1, 4, 7, 2, 9, 3, 8, 5, 6, 2];
    let reference = {
        let eng = engine_with_threads(1);
        let mut c = lexico::cache::full::FullCache::new(eng.shape());
        let (l, st) = eng.prefill_capture(&toks[..6], &mut c);
        let l2 = eng.prefill_suffix(&st, &toks[6..], &mut c);
        (l, st.ks, st.vs, l2)
    };
    for &threads in &THREAD_COUNTS[1..] {
        let eng = engine_with_threads(threads);
        let mut c = lexico::cache::full::FullCache::new(eng.shape());
        let (l, st) = eng.prefill_capture(&toks[..6], &mut c);
        let l2 = eng.prefill_suffix(&st, &toks[6..], &mut c);
        assert_eq!(l, reference.0, "T={threads}: prefix logits diverged");
        assert_eq!(st.ks, reference.1, "T={threads}: captured K rows diverged");
        assert_eq!(st.vs, reference.2, "T={threads}: captured V rows diverged");
        assert_eq!(l2, reference.3, "T={threads}: suffix logits diverged");
    }
}

#[test]
fn mixed_prefilling_and_decoding_rounds_are_thread_invariant() {
    // Chunked prefill interleaved with decode at T ∈ {1, 2, 4}: a long
    // prompt admitted mid-stream consumes one 3-token chunk per round
    // while earlier sessions keep decoding (and a fan-out request seats
    // its candidates when its last chunk lands). Every thread count must
    // reproduce the T = 1 responses byte for byte — the chunked-prefill
    // path runs the same sharded kernels as monolithic prefill, so the
    // determinism contract spans scheduling phases too.
    let run = |threads: usize| -> Vec<Response> {
        let engine = Arc::new(engine_with_threads(threads));
        let dicts = tiny_dicts(engine.shape(), 64);
        let metrics = Arc::new(Mutex::new(Metrics::new()));
        let cfg = BatcherConfig {
            default_method: "lexico:s=2,nb=4".into(),
            prefix_min_tokens: 4,
            prefill_chunk: 3,
            ..Default::default()
        };
        let mut b = Batcher::new(engine, Some(dicts), cfg, metrics);
        let mut replies = Vec::new();
        // two sessions decoding first
        for r in [
            Request::greedy(1, "1+2=", 8, ""),
            Request::greedy(2, "2,7,4>", 8, "full"),
        ] {
            let (tx, rx) = std::sync::mpsc::channel();
            b.enqueue(Job::new(r, tx));
            replies.push(rx);
        }
        for _ in 0..3 {
            b.round();
        }
        // a long prompt and a fan-out request admitted mid-stream
        for r in [
            Request::greedy(3, "k01=v11;k02=v22;k03=v33;k04=v44;k02?", 6, ""),
            Request { fanout: 2, ..Request::greedy(4, "7,3,5>", 5, "") },
        ] {
            let (tx, rx) = std::sync::mpsc::channel();
            b.enqueue(Job::new(r, tx));
            replies.push(rx);
        }
        for _ in 0..128 {
            if !b.has_work() {
                break;
            }
            b.round();
        }
        replies.into_iter().map(|r| r.try_recv().expect("reply pending")).collect()
    };
    let reference = run(1);
    assert!(reference.iter().all(|r| r.error.is_none()));
    for &threads in &THREAD_COUNTS[1..] {
        let got = run(threads);
        assert_eq!(got.len(), reference.len());
        for (g, want) in got.iter().zip(&reference) {
            assert_eq!(g.text, want.text, "T={threads}: primary stream diverged");
            assert_eq!(g.alts, want.alts, "T={threads}: alternates diverged");
            assert_eq!(g.n_generated, want.n_generated, "T={threads}");
        }
    }
}
