//! Parity tests for the batch-first decode pipeline: `Engine::decode_batch`
//! over concurrent sessions with *mixed* cache backends must reproduce the
//! sequential `decode_step` path token-for-token (and logit-for-logit), and
//! the batched cache entry points must match their sequential fallbacks.

use std::sync::Arc;

use lexico::cache::factory::{build_cache, CacheContext};
use lexico::cache::{CacheShape, KvCache};
use lexico::dict::{Dictionary, DictionarySet};
use lexico::model::testutil::tiny_weights;
use lexico::model::Engine;
use lexico::tensor::argmax;
use lexico::util::rng::Rng;

fn tiny_dicts(shape: CacheShape, n_atoms: usize) -> Arc<DictionarySet> {
    Arc::new(DictionarySet {
        keys: (0..shape.n_layers)
            .map(|i| Dictionary::random(shape.head_dim, n_atoms, 1000 + i as u64))
            .collect(),
        values: (0..shape.n_layers)
            .map(|i| Dictionary::random(shape.head_dim, n_atoms, 2000 + i as u64))
            .collect(),
    })
}

/// The serving scenario: ≥3 concurrent sessions, every session on a
/// different cache backend with a different prompt length, advanced for 12
/// rounds by `decode_batch` — tokens and logits must be identical to
/// advancing each session alone with `decode_step`.
#[test]
fn decode_batch_reproduces_sequential_decode_across_mixed_backends() {
    let engine = Engine::new(tiny_weights(60));
    let dicts = tiny_dicts(engine.shape(), 64);
    let ctx = CacheContext::new(engine.shape(), Some(dicts));
    let specs = [
        "full",
        "lexico:s=2,nb=8",
        "lexico:s=2,nb=4,delta=0.4,fp16",
        "lexico:s=1,nb=4,adaptive=16:0.35",
        "kivi:bits=4,g=8,nb=8",
        "pertoken:bits=8,g=8,nb=0",
        "snapkv:cap=24,win=4",
    ];
    let mut rng = Rng::new(3);
    let prompts: Vec<Vec<u32>> = (0..specs.len())
        .map(|i| (0..16 + 5 * i).map(|_| 3 + rng.below(50) as u32).collect())
        .collect();

    // Sequential reference: each session advanced alone.
    let mut seq_tokens: Vec<Vec<u32>> = Vec::new();
    for (spec, prompt) in specs.iter().zip(&prompts) {
        let mut cache = build_cache(spec, &ctx).unwrap();
        let logits = engine.prefill(prompt, &mut *cache);
        let mut tok = argmax(&logits) as u32;
        let mut pos = prompt.len();
        let mut toks = vec![tok];
        for _ in 0..12 {
            let logits = engine.decode_step(tok, pos, &mut *cache);
            tok = argmax(&logits) as u32;
            pos += 1;
            toks.push(tok);
        }
        seq_tokens.push(toks);
    }

    // Batched run: all sessions advanced together, one decode_batch/round.
    let mut caches: Vec<Box<dyn KvCache>> = Vec::new();
    let mut toks: Vec<u32> = Vec::new();
    let mut poss: Vec<usize> = Vec::new();
    let mut bat_tokens: Vec<Vec<u32>> = Vec::new();
    for (spec, prompt) in specs.iter().zip(&prompts) {
        let mut cache = build_cache(spec, &ctx).unwrap();
        let logits = engine.prefill(prompt, &mut *cache);
        caches.push(cache);
        toks.push(argmax(&logits) as u32);
        poss.push(prompt.len());
        bat_tokens.push(vec![*toks.last().unwrap()]);
    }
    for _round in 0..12 {
        let mut refs: Vec<&mut dyn KvCache> =
            caches.iter_mut().map(|c| &mut **c).collect();
        let logits = engine.decode_batch(&toks, &poss, &mut refs);
        drop(refs);
        for i in 0..specs.len() {
            toks[i] = argmax(&logits[i]) as u32;
            poss[i] += 1;
            bat_tokens[i].push(toks[i]);
        }
    }

    for (i, spec) in specs.iter().enumerate() {
        assert_eq!(
            seq_tokens[i], bat_tokens[i],
            "{spec}: batched decode diverged from sequential"
        );
    }
    // compression still reported where expected
    for (i, spec) in specs.iter().enumerate() {
        if i > 0 && !spec.starts_with("pertoken:bits=8") {
            assert!(caches[i].kv_ratio() < 1.0, "{spec} should compress");
        }
    }
}

/// The batched cache entry points must be observationally identical to
/// their per-row fallbacks for every backend (trait-default or overridden).
#[test]
fn cache_batch_entry_points_match_sequential_for_every_backend() {
    let shape = CacheShape { n_layers: 2, n_heads: 4, n_kv_heads: 2, head_dim: 8 };
    let dicts = tiny_dicts(shape, 64);
    let ctx = CacheContext::new(shape, Some(dicts));
    let specs = [
        "full",
        "lexico:s=2,nb=4",
        "lexico:s=2,nb=4,fp16",
        "kivi:bits=2,g=4,nb=4",
        "pertoken:bits=4,g=8,nb=2",
        "zipcache:hi=4,lo=2,g=8,frac=0.25,nb=4",
        "snapkv:cap=24,win=4",
        "pyramidkv:cap=24,win=4",
    ];
    let (kvd, qd) = (shape.kv_dim(), shape.q_dim());
    for spec in specs {
        let mut rng = Rng::new(77);
        let mut seq = build_cache(spec, &ctx).unwrap();
        let mut bat = build_cache(spec, &ctx).unwrap();
        let n = 9;
        let ks = rng.normal_vec(n * kvd);
        let vs = rng.normal_vec(n * kvd);
        for l in 0..shape.n_layers {
            for i in 0..n {
                seq.append(l, &ks[i * kvd..(i + 1) * kvd], &vs[i * kvd..(i + 1) * kvd]);
            }
            bat.append_batch(l, &ks, &vs, n);
        }
        assert_eq!(seq.tokens(), bat.tokens(), "{spec}");
        assert_eq!(seq.mem_bytes(), bat.mem_bytes(), "{spec}");
        let b = 3;
        let qs = rng.normal_vec(b * qd);
        let mut o_seq = vec![0.0; b * qd];
        let mut o_bat = vec![0.0; b * qd];
        for l in 0..shape.n_layers {
            for i in 0..b {
                seq.attend(l, &qs[i * qd..(i + 1) * qd], &mut o_seq[i * qd..(i + 1) * qd]);
            }
            bat.attend_batch(l, &qs, &mut o_bat, b);
            assert_eq!(o_seq, o_bat, "{spec}: attend_batch diverged at layer {l}");
        }
    }
}

/// decode_batch with a single session must equal decode_step outright —
/// the B=1 degenerate case of the pipeline.
#[test]
fn decode_batch_b1_equals_decode_step() {
    let engine = Engine::new(tiny_weights(61));
    let ctx = CacheContext::new(engine.shape(), None);
    let prompt: Vec<u32> = vec![5, 6, 7, 8];
    let mut c1 = build_cache("full", &ctx).unwrap();
    let mut c2 = build_cache("full", &ctx).unwrap();
    let l1 = engine.prefill(&prompt, &mut *c1);
    let l2 = engine.prefill(&prompt, &mut *c2);
    assert_eq!(l1, l2);
    let tok = argmax(&l1) as u32;
    let seq = engine.decode_step(tok, prompt.len(), &mut *c1);
    let mut refs: Vec<&mut dyn KvCache> = vec![&mut *c2];
    let bat = engine.decode_batch(&[tok], &[prompt.len()], &mut refs);
    assert_eq!(seq, bat[0]);
}
