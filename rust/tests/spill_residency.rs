//! Tiered-residency integration tests: the spill → fault round trip is
//! bitwise-invisible to decoding at every thread count, hibernation
//! snapshots reproduce the session exactly, and corrupt page files fail
//! cleanly instead of poisoning the process.
//!
//! The bitwise contract under test (DESIGN.md §11): sealed CSR pages that
//! leave RAM through the spill store and come back through a fault must
//! produce decode logits whose `to_bits()` match a twin cache that never
//! spilled — across random spill/wake schedules, both coefficient
//! precisions, ragged tails, and T ∈ {1, 2, 4} worker threads.

use std::sync::Arc;

use lexico::cache::factory::{build_cache, CacheContext};
use lexico::cache::{CacheShape, KvCache};
use lexico::dict::{Dictionary, DictionarySet};
use lexico::exec::ExecPool;
use lexico::model::testutil::tiny_weights;
use lexico::model::Engine;
use lexico::store::SpillStore;
use lexico::tensor::argmax;
use lexico::util::rng::Rng;

const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

/// Every coefficient mode (FP8, FP16 and the 1-bit sign tier); sparsity 2
/// so the tiny prompts still overflow the recency buffer and seal pages.
const SPECS: [&str; 3] = ["lexico:s=2,nb=4", "lexico:s=2,nb=4,fp16", "lexico:s=2,nb=4,sign"];

/// The spec's context, with the engine pool and a spill store wired
/// through the construction runtime (the batcher's wiring).
fn spill_ctx(eng: &Engine, store: &Arc<SpillStore>) -> CacheContext {
    let mut ctx = CacheContext::new(eng.shape(), Some(tiny_dicts(eng.shape(), 64)));
    ctx.runtime = ctx.runtime.with_pool(eng.pool().clone()).with_spill(store.clone());
    ctx
}

fn tiny_dicts(shape: CacheShape, n_atoms: usize) -> Arc<DictionarySet> {
    Arc::new(DictionarySet {
        keys: (0..shape.n_layers)
            .map(|i| Dictionary::random(shape.head_dim, n_atoms, 1000 + i as u64))
            .collect(),
        values: (0..shape.n_layers)
            .map(|i| Dictionary::random(shape.head_dim, n_atoms, 2000 + i as u64))
            .collect(),
    })
}

fn engine_with_threads(threads: usize) -> Engine {
    Engine::with_pool(tiny_weights(101), Arc::new(ExecPool::new(threads)))
}

fn tmp_store(tag: &str) -> (Arc<SpillStore>, std::path::PathBuf) {
    let dir = std::env::temp_dir().join(format!("lexico_spill_it_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    (Arc::new(SpillStore::open(&dir).expect("spill store")), dir)
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Property test: twin caches decode the same stream while one of them is
/// driven through a random spill / fault / leave-alone schedule between
/// steps. Sealed pages round-trip through the page file; the ragged tail
/// and recency buffer stay resident. Any divergence — even one ULP — fails.
#[test]
fn random_spill_wake_schedules_are_bitwise_identical() {
    for &threads in &THREAD_COUNTS {
        let eng = engine_with_threads(threads);
        let mut ctx = CacheContext::new(eng.shape(), Some(tiny_dicts(eng.shape(), 64)));
        ctx.runtime = ctx.runtime.with_pool(eng.pool().clone());
        for (pi, spec) in SPECS.iter().enumerate() {
            let (store, _dir) = tmp_store(&format!("prop_t{threads}_p{pi}"));
            let mut rng = Rng::new(0xC0FFEE + 31 * threads as u64 + pi as u64);
            // 80-token prompt: 76 CSR tokens per head = 2 sealed pages + a
            // 12-row ragged tail past the 4-token recency buffer
            let prompt: Vec<u32> = (0..80).map(|_| 3 + rng.below(50) as u32).collect();
            let mut plain = build_cache(spec, &ctx).unwrap();
            let mut spilly = build_cache(spec, &spill_ctx(&eng, &store)).unwrap();
            let l0 = eng.prefill(&prompt, &mut *plain);
            let l1 = eng.prefill(&prompt, &mut *spilly);
            assert_eq!(bits(&l0), bits(&l1), "T={threads} {spec}: prefill diverged");
            let mut tok = argmax(&l0) as u32;
            let mut pos = prompt.len();
            for step in 0..40 {
                match rng.below(4) {
                    0 => {
                        spilly.spill_cold().unwrap();
                    }
                    1 => {
                        spilly.fault_resident().unwrap();
                    }
                    _ => {} // attend faults lazily when pages are cold
                }
                let a = eng.decode_step(tok, pos, &mut *plain);
                let b = eng.decode_step(tok, pos, &mut *spilly);
                assert_eq!(
                    bits(&a),
                    bits(&b),
                    "T={threads} {spec}: logits diverged at step {step} \
                     (spilled {} B)",
                    spilly.spilled_bytes()
                );
                tok = argmax(&a) as u32;
                pos += 1;
            }
            let (spilled_pages, _, faults, _) = store.counters();
            assert!(spilled_pages > 0, "T={threads} {spec}: schedule never spilled (vacuous)");
            assert!(faults > 0, "T={threads} {spec}: schedule never faulted (vacuous)");
        }
    }
}

/// Hibernate → restore (the cross-process snapshot path) must reproduce
/// the exact stream the un-snapshotted cache would have produced.
#[test]
fn hibernate_restore_continues_the_stream_bitwise_across_thread_counts() {
    for &threads in &THREAD_COUNTS {
        let eng = engine_with_threads(threads);
        for (pi, spec) in SPECS.iter().enumerate() {
            let (store, _dir) = tmp_store(&format!("snap_t{threads}_p{pi}"));
            let ctx = spill_ctx(&eng, &store);
            let mut rng = Rng::new(0xBEEF + threads as u64 + 7 * pi as u64);
            let prompt: Vec<u32> = (0..70).map(|_| 3 + rng.below(50) as u32).collect();
            let mut live = build_cache(spec, &ctx).unwrap();
            let logits = eng.prefill(&prompt, &mut *live);
            let mut tok = argmax(&logits) as u32;
            let mut pos = prompt.len();
            for _ in 0..8 {
                let l = eng.decode_step(tok, pos, &mut *live);
                tok = argmax(&l) as u32;
                pos += 1;
            }
            let blob = live.hibernate_state().expect("hibernate");
            let mut revived = build_cache(spec, &ctx).unwrap();
            revived.restore_hibernated(&blob).expect("restore");
            assert_eq!(revived.tokens(), live.tokens());
            // both continue 10 more steps — identical logits every step
            let mut tok2 = tok;
            let mut pos2 = pos;
            for step in 0..10 {
                let a = eng.decode_step(tok, pos, &mut *live);
                let b = eng.decode_step(tok2, pos2, &mut *revived);
                assert_eq!(
                    bits(&a),
                    bits(&b),
                    "T={threads} {spec}: revived stream diverged at step {step}"
                );
                tok = argmax(&a) as u32;
                tok2 = argmax(&b) as u32;
                pos += 1;
                pos2 += 1;
            }
        }
    }
}

/// Fault-injection: truncated and bit-flipped page files must surface as
/// clean `Err`s from the fault path — never a panic, never silent garbage.
#[test]
fn corrupt_and_truncated_page_files_fail_faults_cleanly() {
    let eng = engine_with_threads(1);
    let mk_spilled = |tag: &str| -> (Box<dyn KvCache>, std::path::PathBuf) {
        let (store, dir) = tmp_store(tag);
        let mut c = build_cache("lexico:s=2,nb=4", &spill_ctx(&eng, &store)).unwrap();
        let prompt: Vec<u32> = (0..70).map(|i| 3 + (i % 50) as u32).collect();
        let _ = eng.prefill(&prompt, &mut *c);
        let (n, freed) = c.spill_cold().unwrap();
        assert!(n > 0 && freed > 0.0, "nothing spilled — fixture broken");
        (c, dir.join("pages.lxp"))
    };

    // bit flip in the middle of the file: checksum (or header) validation
    // must reject the page
    let (mut c, pages) = mk_spilled("flip");
    let mut bytes = std::fs::read(&pages).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&pages, &bytes).unwrap();
    assert!(c.fault_resident().is_err(), "bit-flipped page must fail the fault");

    // truncation: a fault whose page extends past EOF must error, not read
    // garbage
    let (mut c, pages) = mk_spilled("trunc");
    let bytes = std::fs::read(&pages).unwrap();
    std::fs::write(&pages, &bytes[..bytes.len() / 2]).unwrap();
    assert!(c.fault_resident().is_err(), "truncated page file must fail the fault");

    // and a healthy twin still faults fine (the harness itself is sound)
    let (mut c, _pages) = mk_spilled("ok");
    c.fault_resident().expect("clean fault");
    assert_eq!(c.spilled_bytes(), 0.0);
}
