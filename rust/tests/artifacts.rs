//! Artifact-dependent integration tests: these need `make artifacts` to
//! have run (trained weights, dictionaries, HLO graphs). Each test skips
//! gracefully when the artifacts are absent so `cargo test` stays green on
//! a fresh checkout.

use std::path::PathBuf;
use std::sync::Arc;

use lexico::cache::full::FullCache;
use lexico::dict::DictionarySet;
use lexico::model::{Engine, Weights};
use lexico::runtime::PjrtEngine;
use lexico::tasks;

fn artifacts() -> Option<PathBuf> {
    // tests run from the crate root
    let dir = lexico::artifacts_dir();
    dir.join("model_M.bin").exists().then_some(dir)
}

/// Tokenizer contract: Rust VOCAB_CHARS == artifacts/vocab.txt (written by
/// the Python side — the single source of truth check).
#[test]
fn cross_language_vocab_contract() {
    let Some(dir) = artifacts() else { return };
    let vocab = std::fs::read_to_string(dir.join("vocab.txt")).unwrap();
    assert_eq!(vocab, tasks::VOCAB_CHARS, "vocab.txt diverged from tasks::VOCAB_CHARS");
}

/// The trained M model is a competent LM: held-out perplexity must be far
/// below both uniform (=vocab) and unigram levels. (Task *accuracy* did not
/// emerge at the 1-core training budget — see EXPERIMENTS.md §Setup — so
/// quality comparisons use perplexity + full-cache agreement.)
#[test]
fn trained_model_is_a_competent_lm() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(Weights::load(dir.join("model_M.bin")).unwrap());
    let r = lexico::eval::evaluate(
        &engine, None, "full",
        &lexico::eval::EvalConfig::new(tasks::Task::Lm, 3, 4242),
    )
    .unwrap();
    assert!(r.score < 6.0, "held-out ppl {:.2} — model did not train", r.score);
}

/// Dictionaries load, have unit-norm atoms, and reconstruct real keys much
/// better than random dictionaries (Table 1's headline claim).
#[test]
fn dictionaries_beat_random_on_real_keys() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(Weights::load(dir.join("model_M.bin")).unwrap());
    let dicts = DictionarySet::load(dir.join("dict_M_N1024.bin")).unwrap();
    let shape = engine.shape();
    let layer = shape.n_layers / 2;
    // collect keys from a held-out prompt
    let mut rng = lexico::util::rng::Rng::new(777);
    let text = tasks::gen_lm_text(&mut rng, 200);
    let mut ids = vec![tasks::BOS];
    ids.extend(tasks::encode(&text));
    let mut cache = FullCache::new(shape);
    let _ = engine.prefill(&ids, &mut cache);
    let kvd = shape.kv_dim();
    let m = shape.head_dim;
    let ks = cache.keys(layer);
    let t = ks.len() / kvd;
    let dict = &dicts.keys[layer];
    let rand = lexico::dict::Dictionary::random(m, dict.n, 5);
    let (mut e_d, mut e_r) = (0.0f64, 0.0f64);
    for ti in 0..t {
        let x = &ks[ti * kvd..ti * kvd + m];
        let cd = lexico::omp::omp_encode_alloc(&dict.atoms, dict.n, m, x, 8, 0.0);
        let cr = lexico::omp::omp_encode_alloc(&rand.atoms, rand.n, m, x, 8, 0.0);
        e_d += lexico::omp::rel_error(&dict.atoms, m, x, &cd) as f64;
        e_r += lexico::omp::rel_error(&rand.atoms, m, x, &cr) as f64;
    }
    // The full Table-1 protocol (K and V, 4 corpora, n=600) shows ~0.75x;
    // this spot check uses one layer's keys on one prompt, where the gap
    // is narrower — require strictly better with a small margin.
    assert!(
        e_d < 0.97 * e_r,
        "trained dict ({:.3}) not better than random ({:.3})",
        e_d / t as f64,
        e_r / t as f64
    );
}

/// Lexico at s=8 (≈40–50% KV incl. buffer) must decode with high fidelity
/// to the full cache, and fidelity must degrade monotonically-ish with s.
#[test]
fn lexico_preserves_fidelity_at_high_sparsity() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::new(Weights::load(dir.join("model_M.bin")).unwrap());
    let dicts = Arc::new(DictionarySet::load(dir.join("dict_M_N1024.bin")).unwrap());
    let lex8 = lexico::eval::evaluate(
        &engine, Some(dicts.clone()), "lexico:s=8,nb=32",
        &lexico::eval::EvalConfig::new(tasks::Task::Needle, 12, 31),
    )
    .unwrap();
    assert!(lex8.kv_ratio < 0.65, "kv {}", lex8.kv_ratio);
    assert!(
        lex8.agree >= 60.0,
        "lexico s=8 full-cache agreement only {:.1}%",
        lex8.agree
    );
    let lex1 = lexico::eval::evaluate(
        &engine, Some(dicts), "lexico:s=1,nb=4",
        &lexico::eval::EvalConfig::new(tasks::Task::Needle, 12, 31),
    )
    .unwrap();
    assert!(
        lex1.agree <= lex8.agree + 10.0,
        "s=1 ({:.1}%) should not beat s=8 ({:.1}%)",
        lex1.agree,
        lex8.agree
    );
}

/// PJRT path: the AOT prefill+decode graphs must produce exactly the same
/// greedy generation as the native engine (the three-layer composition
/// proof).
#[test]
fn pjrt_matches_native_generation() {
    let Some(dir) = artifacts() else { return };
    if !dir.join("model.hlo.txt").exists() {
        return;
    }
    let pjrt = PjrtEngine::load(&dir, &dir.join("model_M.bin")).unwrap();
    let native = Engine::new(Weights::load(dir.join("model_M.bin")).unwrap());
    let mut rng = lexico::util::rng::Rng::new(99);
    for _ in 0..3 {
        let inst = tasks::gen_needle(&mut rng, 10);
        let mut prompt = vec![tasks::BOS];
        prompt.extend(tasks::encode(&inst.prompt));
        // numeric equivalence of the prefill logits (argmax chains can flip
        // on near-tie logits, so token-sequence equality is too strict)
        let (pl, nl) = (
            pjrt.prefill_logits(&prompt).unwrap(),
            {
                let mut cache = FullCache::new(native.shape());
                native.prefill(&prompt, &mut cache)
            },
        );
        let maxd = pl
            .iter()
            .zip(&nl)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(maxd < 1e-3, "prefill logits diverge: max |Δ| = {maxd}");
        // and the greedy first token agrees
        let a = pjrt.generate(&prompt, 1, None).unwrap();
        let mut cache = FullCache::new(native.shape());
        let b = native.generate(&prompt, 1, None, &mut cache);
        assert_eq!(a, b, "first decoded token differs on {:?}", inst.prompt);
    }
}

/// The standalone L1 OMP kernel artifact agrees with the native Rust OMP.
#[test]
fn pjrt_omp_kernel_matches_native() {
    let Some(dir) = artifacts() else { return };
    if !dir.join("omp_M.hlo.txt").exists() {
        return;
    }
    let pjrt = PjrtEngine::load(&dir, &dir.join("model_M.bin")).unwrap();
    let dicts = DictionarySet::load(dir.join("dict_M_N1024.bin")).unwrap();
    let d = &dicts.keys[0];
    let batch = 64;
    let m = d.m;
    let mut rng = lexico::util::rng::Rng::new(3);
    let x: Vec<f32> = rng.normal_vec(batch * m);
    // column-major [m, N] layout for the artifact input
    let mut dmn = vec![0.0f32; m * d.n];
    for a in 0..d.n {
        for i in 0..m {
            dmn[i * d.n + a] = d.atoms[a * m + i];
        }
    }
    let (idx, val, nnz) = pjrt.run_omp(&dmn, &x).unwrap();
    let s = 8;
    for b in 0..batch {
        let native = lexico::omp::omp_encode_alloc(&d.atoms, d.n, m, &x[b * m..(b + 1) * m], s, 0.0);
        assert_eq!(nnz[b] as usize, native.nnz(), "row {b} nnz");
        // same support (order-sensitive: both are greedy OMP)
        let kernel_idx: Vec<u16> = idx[b * s..b * s + native.nnz()]
            .iter()
            .map(|&i| i as u16)
            .collect();
        assert_eq!(kernel_idx, native.idx, "row {b} support");
        for j in 0..native.nnz() {
            let kv = val[b * s + j];
            assert!(
                (kv - native.val[j]).abs() < 1e-3 + 1e-2 * native.val[j].abs(),
                "row {b} coef {j}: {kv} vs {}",
                native.val[j]
            );
        }
    }
}
